#!/usr/bin/env bash
# Tier-1 verify gate (ROADMAP.md): the full test suite, -x -q, followed by a
# serving smoke run (paged engine end-to-end through launch/serve).
#
# Known version-gated skips (jax < 0.5 lacks jax.sharding.AxisType /
# jax.set_mesh) show up as SKIPPED with a reason, not failures — see
# tests/test_distributed.py and tests/test_checkpoint.py.
#
# Usage: scripts/verify.sh [extra pytest args]
#   e.g. scripts/verify.sh -m tier1      # only the tier1-marked fast gate
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q "$@"
echo "--- serving smoke (paged engine) ---"
python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
    --requests 3 --max-new 4 --slots 2 --max-len 64
