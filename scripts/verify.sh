#!/usr/bin/env bash
# Tier-1 verify gate (ROADMAP.md): the full test suite, -x -q, followed by a
# serving smoke run (paged engine end-to-end through launch/serve).
#
# Known version-gated skips (jax < 0.5 lacks jax.sharding.AxisType /
# jax.set_mesh) show up as SKIPPED with a reason, not failures — see
# tests/test_distributed.py and tests/test_checkpoint.py.
#
# Usage: scripts/verify.sh [extra pytest args]
#   e.g. scripts/verify.sh -m tier1      # only the tier1-marked fast gate
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q "$@"
echo "--- serving smoke (paged engine) ---"
python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
    --requests 3 --max-new 4 --slots 2 --max-len 64
echo "--- paged-attention kernel parity smoke (interpret mode) ---"
python - <<'PY'
import jax, jax.numpy as jnp, numpy as np
from repro.models import registry, transformer as tf
from repro.serving import ServeConfig, ServingEngine

cfg = registry.get_config("qwen1.5-0.5b", smoke=True)
params = tf.init_params(cfg, jax.random.PRNGKey(0))
prompts = [[3, 1, 4, 1, 5], [9, 2, 6]]

def run(mode):
    eng = ServingEngine(cfg, params, ServeConfig(
        slots=2, max_len=64, block_size=8, prefill_chunk=8,
        paged_attn_kernel=mode))
    rids = [eng.submit(p, max_new_tokens=3) for p in prompts]
    res = eng.run()
    return [res[r] for r in rids]

gather, kernel = run("ref"), run("interpret")
assert gather == kernel, (gather, kernel)
print(f"paged-attention parity OK (gather == kernel): {kernel}")
PY
echo "--- prefix-cache smoke (shared system prompt, parity vs off) ---"
python - <<'PY'
import jax, numpy as np
from repro.models import registry, transformer as tf
from repro.serving import ServeConfig, ServingEngine

cfg = registry.get_config("qwen1.5-0.5b", smoke=True)
params = tf.init_params(cfg, jax.random.PRNGKey(0))
system = list(range(100, 124))            # 24-token shared system prompt
prompts = [system + [7, 8], system + [9], system + [11, 12, 13]]

def run(prefix):
    eng = ServingEngine(cfg, params, ServeConfig(
        slots=2, max_len=64, block_size=8, prefill_chunk=8,
        prefix_cache=prefix))
    outs = []
    for p in prompts:                     # sequential: later turns can hit
        rid = eng.submit(p, max_new_tokens=4)
        eng.run()
        outs.append(eng.result(rid))
    return outs, eng

cold, _ = run(False)
warm, eng = run(True)
assert warm == cold, (warm, cold)
hit_rate = eng.prefix.hit_rate()
hit_tokens = eng.prefix.hit_tokens
assert hit_rate > 0 and hit_tokens > 0, (hit_rate, hit_tokens)
eng.kv.check_invariants(eng.prefix.held_blocks())
print(f"prefix-cache parity OK (shared == cold), hit_rate={hit_rate:.2f} "
      f"hit_tokens={hit_tokens}")
PY
echo "--- speculation smoke (batched verify, greedy parity vs off) ---"
python - <<'PY'
import jax, numpy as np
from repro.models import registry, transformer as tf
from repro.serving import ServeConfig, ServingEngine

cfg = registry.get_config("qwen1.5-0.5b", smoke=True)
params = tf.init_params(cfg, jax.random.PRNGKey(0))
# repetitive multi-turn trace: prompt-lookup self-drafting feeds on it
prompts = [np.tile([5, 6, 7, 8], 6).tolist(), [1, 2, 3],
           np.tile([9, 3], 10).tolist()]

def run(spec):
    eng = ServingEngine(cfg, params, ServeConfig(
        slots=2, max_len=128, speculation=spec,
        draft_len=4 if spec else 0))
    rids = [eng.submit(p, max_new_tokens=16) for p in prompts]
    res = eng.run()
    return [res[r] for r in rids], eng

off, _ = run(False)
on, eng = run(True)
assert on == off, (on, off)
acc = eng.acceptance_rate()
assert acc > 0, acc
eng.kv.check_invariants()
print(f"speculation parity OK (spec == off), acceptance_rate={acc:.2f} "
      f"steps={len(eng.metrics)} traces={eng.trace_counts}")
PY
echo "--- observability smoke (trace + metrics through launch/serve) ---"
OBS_DIR="$(mktemp -d)"
trap 'rm -rf "$OBS_DIR"' EXIT
python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
    --requests 3 --max-new 4 --slots 2 --max-len 64 \
    --trace-out "$OBS_DIR/trace.json" --metrics-out "$OBS_DIR/metrics.jsonl"
OBS_DIR="$OBS_DIR" python - <<'PY'
import json, math, os

d = os.environ["OBS_DIR"]
doc = json.load(open(os.path.join(d, "trace.json")))
evs = doc["traceEvents"]
assert evs, "empty trace"
pids = {e["pid"] for e in evs}
assert {1, 2, 3} <= pids, f"missing trace tracks: {pids}"   # serving/requests/kernel
assert any(e.get("ph") == "X" and e["name"] == "step" for e in evs)
assert any(e.get("cat") == "modeled" for e in evs), "no kernel lanes"
(line,) = open(os.path.join(d, "metrics.jsonl")).read().splitlines()[-1:]
snap = json.loads(line)
req = snap["requests"]
for hist in ("ttft", "tpot"):
    s = req[hist]
    assert s["count"] > 0, f"no {hist} observations"
    for q in ("p50", "p99"):
        assert math.isfinite(s[q]) and s[q] > 0, (hist, q, s)
assert snap["ledger"]["total_hbm_bytes"] > 0
print(f"observability smoke OK: {len(evs)} trace events "
      f"({doc['otherData']['dropped_events']} dropped), "
      f"ttft_p50={req['ttft']['p50']:.3f}s tpot_p50={req['tpot']['p50']:.4f}s")
PY
