#!/usr/bin/env bash
# Tier-1 verify gate (ROADMAP.md): the full test suite, -x -q.
#
# Known version-gated skips (jax < 0.5 lacks jax.sharding.AxisType /
# jax.set_mesh) show up as SKIPPED with a reason, not failures — see
# tests/test_distributed.py and tests/test_checkpoint.py.
#
# Usage: scripts/verify.sh [extra pytest args]
#   e.g. scripts/verify.sh -m tier1      # only the tier1-marked fast gate
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
