"""Pallas paged-attention kernel (interpret mode): logits-level parity with
the gather+_sdpa read path across GQA, MLA, and sliding-window attention, in
both prefill-chunk and decode — including ragged last blocks, inactive lanes
parked on null block 0, heterogeneous decode positions, and the ring-depth
planner feeding it."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.schedule import (TimingCache, plan_paged_attn,
                                 set_default_timing_cache)
from repro.kernels.ops import paged_attn, resolve_paged_attn_mode
from repro.models import attention as A
from repro.models import registry
from repro.models import transformer as tf
from repro.models.layers import init_from_specs

pytestmark = pytest.mark.tier1

GQA = dict(d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
           dtype=jnp.float32)
WINDOW = dict(GQA, window=16)
MLA = dict(d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
           kv_lora_rank=32, rope_head_dim=8, dtype=jnp.float32)
CFGS = {"gqa": GQA, "window": WINDOW, "mla": MLA}


def _attn_cfg(name, mode):
    return A.AttnConfig(**{**CFGS[name], "paged_mode": mode})


def _pools(c, nb, bs, seed=1):
    key = jax.random.PRNGKey(seed)
    return {k: jax.random.normal(key, s.shape, s.dtype) * 0.3
            for k, s in A.paged_cache_specs(c, nb, bs).items()}


# ---------------------------------------------------------------------------
# op-level parity: kernels.ops.paged_attn interpret vs ref
# ---------------------------------------------------------------------------

class TestOpParity:
    def test_gqa_heterogeneous_positions_and_ragged_tails(self):
        """Lanes at unaligned positions (ragged last blocks), one lane with a
        short context, tables in scrambled physical order."""
        c = _attn_cfg("gqa", "auto")
        nb, bs, MB, B = 11, 8, 4, 3
        pools = _pools(c, nb, bs)
        tables = jnp.asarray([[7, 2, 9, 4], [1, 5, 0, 0], [3, 6, 8, 10]],
                             jnp.int32)
        positions = jnp.asarray([26, 9, 31], jnp.int32)   # ragged, full
        q = jax.random.normal(jax.random.PRNGKey(2), (B, 1, 4, 16))
        kw = dict(num_kv_heads=2, scale=0.25)
        ref = paged_attn(q, pools["k"], pools["v"], tables, positions,
                         mode="ref", **kw)
        got = paged_attn(q, pools["k"], pools["v"], tables, positions,
                         mode="interpret", **kw)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_inactive_lane_parked_on_null_block(self):
        """A lane whose table is all zeros (never mapped / preempted) must
        not produce NaN/Inf — its rows read the null block and are fully
        position-masked except slot 0."""
        c = _attn_cfg("gqa", "auto")
        pools = _pools(c, 5, 8)
        tables = jnp.asarray([[1, 2, 0, 0], [0, 0, 0, 0]], jnp.int32)
        positions = jnp.asarray([12, 0], jnp.int32)
        q = jax.random.normal(jax.random.PRNGKey(3), (2, 1, 4, 16))
        kw = dict(num_kv_heads=2, scale=0.25)
        ref = paged_attn(q, pools["k"], pools["v"], tables, positions,
                         mode="ref", **kw)
        got = paged_attn(q, pools["k"], pools["v"], tables, positions,
                         mode="interpret", **kw)
        assert np.isfinite(np.asarray(got)).all()
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_window_expiry_masked_per_block(self):
        c = _attn_cfg("window", "auto")
        pools = _pools(c, 9, 8)
        tables = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
        positions = jnp.asarray([29], jnp.int32)
        q = jax.random.normal(jax.random.PRNGKey(4), (1, 1, 4, 16))
        kw = dict(num_kv_heads=2, scale=0.25, window=16)
        ref = paged_attn(q, pools["k"], pools["v"], tables, positions,
                         mode="ref", **kw)
        got = paged_attn(q, pools["k"], pools["v"], tables, positions,
                         mode="interpret", **kw)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_prefill_chunk_queries(self):
        """S > 1 block-aligned chunk: causal within the chunk + full prefix."""
        c = _attn_cfg("gqa", "auto")
        pools = _pools(c, 9, 8)
        tables = jnp.asarray([[5, 1, 4, 2]], jnp.int32)
        positions = jnp.asarray([16], jnp.int32)          # chunk 3 of 4
        q = jax.random.normal(jax.random.PRNGKey(5), (1, 16, 4, 16))
        kw = dict(num_kv_heads=2, scale=0.25)
        ref = paged_attn(q, pools["k"], pools["v"], tables, positions,
                         mode="ref", **kw)
        got = paged_attn(q, pools["k"], pools["v"], tables, positions,
                         mode="interpret", **kw)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_mla_latent_mqa_form(self):
        c = _attn_cfg("mla", "auto")
        pools = _pools(c, 7, 8)
        tables = jnp.asarray([[2, 4, 1, 6], [3, 5, 0, 0]], jnp.int32)
        positions = jnp.asarray([25, 10], jnp.int32)
        dk = 32 + 8                                       # kv_lora + rope
        q = jax.random.normal(jax.random.PRNGKey(6), (2, 1, 4, dk))
        kw = dict(num_kv_heads=1, scale=0.2, mla=True)
        ref = paged_attn(q, pools["c_kv"], pools["k_rope"], tables, positions,
                         mode="ref", **kw)
        got = paged_attn(q, pools["c_kv"], pools["k_rope"], tables, positions,
                         mode="interpret", **kw)
        assert ref.shape == (2, 1, 4, 32)                 # latent-space output
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_ring_depths_agree(self):
        """G = 1 (in-situ), 2 (naive ping-pong), 4 (GPP) all reproduce the
        same output — the ring depth is a throughput knob, not semantics."""
        c = _attn_cfg("gqa", "auto")
        pools = _pools(c, 9, 8)
        tables = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32)
        positions = jnp.asarray([31, 17], jnp.int32)
        q = jax.random.normal(jax.random.PRNGKey(7), (2, 1, 4, 16))
        from repro.kernels.paged_attention import paged_attention
        outs = [paged_attention(q, pools["k"], pools["v"], tables, positions,
                                num_kv_heads=2, scale=0.25, num_bufs=G,
                                interpret=True) for G in (1, 2, 4)]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                       rtol=1e-6, atol=1e-6)

    def test_mode_validation(self):
        with pytest.raises(ValueError):
            resolve_paged_attn_mode("bogus")
        assert resolve_paged_attn_mode("ref") == "ref"
        assert resolve_paged_attn_mode("pallas") == "pallas"


# ---------------------------------------------------------------------------
# attention-level parity: the *_paged model fns under the cfg knob
# ---------------------------------------------------------------------------

class TestAttentionLevelParity:
    @pytest.mark.parametrize("name", ("gqa", "window", "mla"))
    def test_decode_paged(self, name):
        cref, cker = _attn_cfg(name, "ref"), _attn_cfg(name, "interpret")
        p = init_from_specs(A.attn_specs(cref), jax.random.PRNGKey(0))
        pools = _pools(cref, 9, 8)
        tables = jnp.asarray([[1, 2, 3, 4], [5, 6, 0, 0]], jnp.int32)
        positions = jnp.asarray([27, 11], jnp.int32)
        active = jnp.asarray([True, True])
        x = jax.random.normal(jax.random.PRNGKey(8), (2, 1, 64)) * 0.5
        fn = A.mla_decode_paged if cref.is_mla else A.gqa_decode_paged
        ref, cache_r = fn(p, cref, x, pools, tables, positions, active)
        got, cache_k = fn(p, cker, x, pools, tables, positions, active)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=1e-4, atol=1e-4)
        # the write path is shared: caches must be bit-identical
        for kk in cache_r:
            np.testing.assert_array_equal(np.asarray(cache_r[kk]),
                                          np.asarray(cache_k[kk]))

    @pytest.mark.parametrize("name", ("gqa", "window", "mla"))
    def test_prefill_chunk_paged(self, name):
        cref, cker = _attn_cfg(name, "ref"), _attn_cfg(name, "interpret")
        p = init_from_specs(A.attn_specs(cref), jax.random.PRNGKey(0))
        pools = _pools(cref, 9, 8)
        table_row = jnp.asarray([[3, 1, 4, 2]], jnp.int32)
        x = jax.random.normal(jax.random.PRNGKey(9), (1, 16, 64)) * 0.5
        fn = A.mla_prefill_paged if cref.is_mla else A.gqa_prefill_paged
        ref, _ = fn(p, cref, x, pools, table_row, 8)
        got, _ = fn(p, cker, x, pools, table_row, 8)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# transformer-level parity: full models through cfg.paged_attn_kernel
# ---------------------------------------------------------------------------

PARITY_ARCHS = ("qwen1.5-0.5b", "gemma3-12b", "deepseek-v2-lite-16b")


class TestTransformerLevelParity:
    @pytest.mark.parametrize("arch", PARITY_ARCHS)
    def test_chunked_prefill_and_decode_logits(self, arch):
        """prefill_chunk + decode_step_paged produce the same logits whether
        the paged read gathers pools ("ref") or streams KV blocks through
        the Pallas kernel ("interpret") — across the three attention
        families (GQA+bias, local:global window, MLA+MoE)."""
        cfg = registry.get_config(arch, smoke=True)
        params = tf.init_params(cfg, jax.random.PRNGKey(0))
        bs, chunk, mb = 8, 8, 4
        rng = np.random.default_rng(7)
        prompt = rng.integers(0, cfg.vocab_size, size=13)
        table_row = jnp.arange(1, mb + 1, dtype=jnp.int32)[None]

        def drive(mode):
            c = cfg.with_(paged_attn_kernel=mode)
            specs = tf.paged_cache_specs(c, num_blocks=mb + 1, block_size=bs)
            caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), specs)
            outs = []
            for c0 in range(0, 16, chunk):
                ctoks = np.zeros(chunk, np.int32)
                real = prompt[c0: min(len(prompt), c0 + chunk)]
                ctoks[: len(real)] = real
                last = len(prompt) - 1 - c0 if c0 + chunk >= 16 else 0
                logits, caches = tf.prefill_chunk(
                    params, c, jnp.asarray(ctoks[None]), caches, table_row,
                    c0, last)
            outs.append(np.asarray(logits, np.float32))
            tok, pos = int(prompt[-1]), len(prompt)
            for _ in range(2):
                logits, caches = tf.decode_step_paged(
                    params, c, jnp.asarray([[tok]], jnp.int32), caches,
                    table_row, jnp.asarray([pos], jnp.int32),
                    jnp.asarray([True]))
                outs.append(np.asarray(logits, np.float32))
                tok = int(np.argmax(outs[-1][0, -1]))
                pos += 1
            return outs

        ref, ker = drive("ref"), drive("interpret")
        for a, b in zip(ref, ker):
            np.testing.assert_allclose(b, a, rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------

class TestPlanPagedAttn:
    def test_ring_grows_with_dma_pressure(self):
        """DMA-bound blocks (few query rows) want a deeper ring."""
        small_q = plan_paged_attn(block_bytes=1 << 20, compute_flops=1e6)
        big_q = plan_paged_attn(block_bytes=1 << 20, compute_flops=1e12)
        assert small_q.num_bufs > big_q.num_bufs >= 2
        assert small_q.chunks == small_q.num_bufs - 1

    def test_vmem_budget_shrinks_ring(self):
        p = plan_paged_attn(block_bytes=1 << 20, compute_flops=1e6,
                            vmem_budget=3 << 20)
        assert p.num_bufs <= 3
        assert p.vmem_bytes <= 3 << 20

    def test_budget_too_small_raises(self):
        with pytest.raises(ValueError):
            plan_paged_attn(block_bytes=4 << 20, compute_flops=1e6,
                            vmem_budget=1 << 20)

    def test_pinned_ring_honored(self):
        assert plan_paged_attn(block_bytes=1 << 20, compute_flops=1e6,
                               num_bufs=2).num_bufs == 2

    def test_timing_cache_feeds_ring_depth(self):
        """A measured fast-link/slow-compute host flips the plan toward a
        shallow ring; the ambient default cache is honored too."""
        fast_link = TimingCache()
        fast_link.record(block_bytes=1e6, compute_flops=1e9,
                         t_dma=1e-5, t_compute=1e-2)
        deep = plan_paged_attn(block_bytes=1 << 20, compute_flops=1e6)
        shallow = plan_paged_attn(block_bytes=1 << 20, compute_flops=1e6,
                                  timing=fast_link)
        assert shallow.num_bufs <= deep.num_bufs
        set_default_timing_cache(fast_link)
        try:
            ambient = plan_paged_attn(block_bytes=1 << 20, compute_flops=1e6)
            assert ambient.num_bufs == shallow.num_bufs
        finally:
            set_default_timing_cache(None)
