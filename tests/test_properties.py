"""Hypothesis property tests on system invariants."""
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.core import analytical as ana
from repro.core import schedule as sched
from repro.core import simulator as dessim
from repro.core.analytical import PimConfig
from repro.core.schedule import plan_stream
from repro.models import moe as moe_mod
from repro.models.layers import cross_entropy, cross_entropy_chunked, init_from_specs


class TestSchedulePlanProperties:
    @given(st.floats(1e3, 1e12), st.floats(1e6, 1e15),
           st.floats(1e9, 1e13), st.floats(1e8, 1e12))
    @settings(max_examples=60)
    def test_ring_depth_covers_transfer(self, block_bytes, flops, fps, bps):
        """G-1 in-flight buffers always cover the transfer/compute ratio, so
        a GPP ring never starves compute (the paper's zero-idle claim)."""
        p = plan_stream(block_bytes=block_bytes, compute_flops=flops,
                        flops_per_s=fps, transfer_bytes_per_s=bps, max_ring=64)
        assert p.ring_depth >= 2
        if p.ring_depth < 64:  # not clamped
            assert (p.ring_depth - 1) * p.t_compute >= p.t_transfer * (1 - 1e-9)

    @given(st.floats(0.1, 10.0))
    @settings(max_examples=30)
    def test_gpp_groups_match_ratio(self, ratio):
        c = PimConfig().with_(n_in=ratio * PimConfig().size_ou / PimConfig().s)
        g = sched.gpp_group_count(c)
        assert g >= 2
        ideal = (c.time_pim + c.time_rewrite) / c.time_rewrite
        if ideal < 2:
            assert g == 2  # clamped: ping-pong is the minimum viable ring
        else:
            # group period fits the rewrite slots: G*t_rw ~ t_pim + t_rw
            assert abs(g - ideal) <= 0.5 + 1e-9


class TestConservationLaws:
    @given(st.sampled_from(["insitu", "naive_pp", "gpp"]),
           st.integers(2, 10), st.floats(0.25, 8), st.integers(1, 4),
           st.floats(4, 256))
    @settings(max_examples=30, deadline=None)
    def test_total_bytes_and_compute_conserved(self, strat, n, ratio, rounds, band):
        c = PimConfig(band=band).with_(n_in=ratio * 32 / 4.0)
        r = dessim.simulate(strat, c, n, rounds)
        assert r.bytes_transferred == pytest.approx(n * rounds * c.size_macro,
                                                    rel=1e-5)
        assert r.compute_cycles == pytest.approx(n * rounds * c.time_pim,
                                                 rel=1e-6)
        # causality: nothing finishes faster than the serial lower bounds
        assert r.total_cycles >= c.time_pim * rounds - 1e-6
        assert r.total_cycles >= (n * rounds * c.size_macro) / band - 1e-6


class TestChunkedCrossEntropy:
    @given(st.integers(1, 4), st.sampled_from([8, 16, 32]),
           st.integers(3, 50), st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_matches_unchunked(self, B, S, V, seed):
        key = jax.random.PRNGKey(seed)
        k1, k2, k3 = jax.random.split(key, 3)
        x = jax.random.normal(k1, (B, S, 8))
        w = jax.random.normal(k2, (V, 8)) * 0.3
        labels = jax.random.randint(k3, (B, S), 0, V)
        head = lambda xc: jnp.einsum("bsd,vd->bsv", xc, w)
        full = cross_entropy(head(x), labels)
        chunked = cross_entropy_chunked(head, x, labels, chunk=8)
        np.testing.assert_allclose(float(full), float(chunked), rtol=1e-5)


class TestMoEProperties:
    @given(st.integers(0, 2**31 - 1), st.sampled_from([1, 2, 4]))
    @settings(max_examples=10, deadline=None)
    def test_high_capacity_conserves_router_mass(self, seed, k):
        """With ample capacity no token is dropped: output is a convex
        combination of expert outputs (finite, grads flow)."""
        cfg = moe_mod.MoeConfig(d_model=16, d_ff=32, num_experts=4,
                                experts_per_token=k, capacity_factor=8.0,
                                dtype=jnp.float32, dispatch_groups=2)
        p = init_from_specs(moe_mod.moe_specs(cfg), jax.random.PRNGKey(seed))
        x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 8, 16))
        y = moe_mod.moe_apply(p, cfg, x)
        assert np.isfinite(np.asarray(y)).all()
        g = jax.grad(lambda pp: (moe_mod.moe_apply(pp, cfg, x) ** 2).mean())(p)
        assert float(jnp.abs(g["w_down"]).sum()) > 0

    @given(st.integers(1, 64), st.integers(1, 8))
    @settings(max_examples=30)
    def test_dispatch_groups_divide(self, T, g):
        cfg = moe_mod.MoeConfig(d_model=8, d_ff=8, num_experts=2,
                                experts_per_token=1, dispatch_groups=g)
        got = moe_mod._dispatch_groups(cfg, T)
        assert got >= 1 and T % got == 0


class TestEq9ConsistencyProperty:
    @given(st.floats(1.0, 200.0))
    @settings(max_examples=40)
    def test_gpp_degradation_between_bounds(self, n):
        """GPP under band/n can't beat no-degradation nor fall below 1/n
        (which is what pure macro-cutting without buffer re-allocation gives)."""
        cfg = PimConfig(size_macro=1024, size_ou=32, s=8.0, n_in=4.0, band=512.0)
        perf = ana.gpp_perf_degradation(cfg, n)
        assert 1.0 / n - 1e-9 <= perf <= 1.0 + 1e-9
        # and strictly better than 1/n for n > 1 (the paper's point)
        if n > 1.5:
            assert perf > 1.0 / n
