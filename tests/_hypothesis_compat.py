"""Import-guard shim for `hypothesis` (not installed in every environment).

Usage in test modules:

    from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

When hypothesis is available these are the real symbols.  When it is not,
`@given(...)` marks the test skipped (instead of the whole module dying at
collection with ModuleNotFoundError, which took every non-hypothesis test in
the file down with it), `@settings(...)` is a no-op, and `st.*` returns inert
placeholders so strategy expressions at decorator level still evaluate.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - exercised in slim images
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _InertStrategy:
        """Placeholder supporting the strategy-combinator surface used in
        decorators (map/filter/flatmap chaining), never executed."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, _name):
            return self

    class _Strategies:
        def __getattr__(self, _name):
            return _InertStrategy()

    st = _Strategies()
