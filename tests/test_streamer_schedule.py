"""Unit tests on the GPP streamer's chunk schedule (pure logic, no mesh)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.streamer import (
    StreamSettings, _chunk_bounds, _layer, _put_chunk, _take_chunk, stream_layers,
)


class TestChunkHelpers:
    @given(st.integers(1, 512), st.integers(1, 8))
    @settings(max_examples=40)
    def test_bounds_partition_exactly(self, dim, chunks):
        if dim < chunks:
            chunks = dim
        spans = [_chunk_bounds(dim, chunks, c) for c in range(chunks)]
        assert spans[0][0] == 0 and spans[-1][1] == dim
        for (a, b), (c, d) in zip(spans, spans[1:]):
            assert b == c and d > c

    def test_take_put_roundtrip(self):
        x = jnp.arange(24.0).reshape(4, 6)
        buf = jnp.zeros_like(x)
        for c in range(3):
            ch = _take_chunk(x, -1, 3, c)
            buf = _put_chunk(buf, ch, -1, 3, c)
        np.testing.assert_array_equal(np.asarray(buf), np.asarray(x))

    def test_layer_dynamic_index(self):
        ws = {"w": jnp.arange(12.0).reshape(3, 2, 2)}
        got = _layer(ws, jnp.asarray(1))
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.arange(4.0, 8.0).reshape(2, 2))


class TestStreamLayersMeshless:
    """Without a mesh the gathers are no-ops but the ring schedule still runs
    — all modes must be exactly the reference composition."""

    def _setup(self, L=6, D=8, B=4, seed=0):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        ws = {"w": jax.random.normal(k1, (L, D, D)) * 0.2}
        x = jax.random.normal(k2, (B, D))
        return ws, x

    def ref(self, ws, x):
        for i in range(ws["w"].shape[0]):
            x = jnp.tanh(x @ ws["w"][i])
        return x

    @pytest.mark.parametrize("mode", ["resident", "insitu", "naive_pp", "gpp"])
    @pytest.mark.parametrize("ring", [2, 3, 5, 8])
    def test_all_modes_match_reference(self, mode, ring):
        ws, x = self._setup()
        apply_fn = lambda c, w: jnp.tanh(c @ w["w"])
        out = stream_layers(
            apply_fn, x, ws, 6,
            settings=StreamSettings(mode=mode, ring_depth=ring),
            mesh=None, shard_specs={"w": None}, full_specs={"w": None})
        np.testing.assert_allclose(np.asarray(out), np.asarray(self.ref(ws, x)),
                                   rtol=1e-5, atol=1e-6)

    @given(st.integers(1, 9), st.integers(2, 8), st.integers(0, 3))
    @settings(max_examples=20, deadline=None)
    def test_gpp_any_depth_any_length(self, L, ring, seed):
        ws, x = self._setup(L=L, seed=seed)
        apply_fn = lambda c, w: jnp.tanh(c @ w["w"])
        out = stream_layers(
            apply_fn, x, ws, L,
            settings=StreamSettings(mode="gpp", ring_depth=ring),
            mesh=None, shard_specs={"w": None}, full_specs={"w": None})
        np.testing.assert_allclose(np.asarray(out), np.asarray(self.ref(ws, x)),
                                   rtol=1e-5, atol=1e-6)

    def test_gpp_differentiable(self):
        ws, x = self._setup()
        apply_fn = lambda c, w: jnp.tanh(c @ w["w"])

        def loss(ws, mode):
            y = stream_layers(apply_fn, x, ws, 6,
                              settings=StreamSettings(mode=mode, ring_depth=4),
                              mesh=None, shard_specs={"w": None},
                              full_specs={"w": None})
            return (y ** 2).sum()

        g_ref = jax.grad(loss)(ws, "resident")
        g_gpp = jax.grad(loss)(ws, "gpp")
        np.testing.assert_allclose(np.asarray(g_gpp["w"]), np.asarray(g_ref["w"]),
                                   rtol=1e-4, atol=1e-6)
