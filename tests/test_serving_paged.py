"""Paged-KV serving engine: greedy parity vs the seed dense-cache engine,
bounded compilation, deterministic sampling, preemption/defrag correctness."""
import numpy as np
import pytest

import jax

from repro.models import registry
from repro.models import transformer as tf
from repro.serving import (DenseServingEngine, ServeConfig, ServingEngine,
                           make_engine)

pytestmark = pytest.mark.tier1

# three attention families: GQA+bias+tied (qwen), sliding-window local:global
# + embed scaling (gemma3), MLA latent cache + MoE + dense prefix (deepseek)
PARITY_ARCHS = ("qwen1.5-0.5b", "gemma3-12b", "deepseek-v2-lite-16b")


@pytest.fixture(scope="module")
def setups():
    out = {}
    for arch in PARITY_ARCHS:
        cfg = registry.get_config(arch, smoke=True)
        out[arch] = (cfg, tf.init_params(cfg, jax.random.PRNGKey(0)))
    return out


def _prompts(cfg, n, lengths=(4, 9, 13, 5, 21)):
    rng = np.random.default_rng(0)
    return [rng.integers(0, cfg.vocab_size, size=l).tolist()
            for l in list(lengths)[:n]]


class TestGreedyParity:
    @pytest.mark.parametrize("arch", PARITY_ARCHS)
    def test_paged_logits_match_dense_path(self, setups, arch):
        """LOGITS-level parity of the paged model path (chunked prefill +
        block-table decode) against the dense prefill/decode path.  Token
        streams from smoke-scale random params degenerate to one repeated
        argmax, so token comparison alone is vacuous — this asserts the
        distributions themselves agree at every step."""
        import jax.numpy as jnp
        cfg, params = setups[arch]
        bs, chunk, max_len = 8, 8, 64
        mb = max_len // bs
        rng = np.random.default_rng(5)
        prompt = rng.integers(0, cfg.vocab_size, size=13)
        toks = jnp.asarray(prompt[None], jnp.int32)

        logits_d, caches_d = tf.prefill(params, cfg, {"tokens": toks},
                                        max_len=max_len)

        specs = tf.paged_cache_specs(cfg, num_blocks=mb + 1, block_size=bs)
        caches_p = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), specs)
        table_row = jnp.arange(1, mb + 1, dtype=jnp.int32)[None]
        padded = 16
        for c0 in range(0, padded, chunk):
            ctoks = np.zeros(chunk, np.int32)
            real = prompt[c0 : min(len(prompt), c0 + chunk)]
            ctoks[: len(real)] = real
            last = len(prompt) - 1 - c0 if c0 + chunk >= padded else 0
            logits_p, caches_p = tf.prefill_chunk(
                params, cfg, jnp.asarray(ctoks[None]), caches_p, table_row,
                c0, last)
        np.testing.assert_allclose(
            np.asarray(logits_p, np.float32),
            np.asarray(logits_d[:, -1], np.float32), rtol=2e-2, atol=2e-2)

        tok = int(np.argmax(np.asarray(logits_p[0], np.float32)))
        pos = len(prompt)
        for _ in range(4):
            t = jnp.asarray([[tok]], jnp.int32)
            logits_d, caches_d = tf.decode_step(params, cfg, t, caches_d, pos)
            logits_p, caches_p = tf.decode_step_paged(
                params, cfg, t, caches_p, table_row,
                jnp.asarray([pos], jnp.int32), jnp.asarray([True]))
            np.testing.assert_allclose(
                np.asarray(logits_p, np.float32),
                np.asarray(logits_d, np.float32), rtol=2e-2, atol=2e-2)
            tok = int(np.argmax(np.asarray(logits_p[0, -1], np.float32)))
            pos += 1

    def test_dense_engine_heterogeneous_lanes_match_solo(self, setups):
        """Regression for the seed engine's per-pos-group decode clobbering
        other lanes' KV: a lane batched with a lane at a different position
        must produce the same stream as when served alone."""
        cfg, params = setups["qwen1.5-0.5b"]
        prompts = _prompts(cfg, 2, (4, 9))   # different lengths => different pos
        solo = []
        for p in prompts:
            eng = DenseServingEngine(cfg, params, ServeConfig(slots=1, max_len=64))
            rid = eng.submit(p, max_new_tokens=5)
            solo.append(eng.run()[rid])
        both = DenseServingEngine(cfg, params, ServeConfig(slots=2, max_len=64))
        rids = [both.submit(p, max_new_tokens=5) for p in prompts]
        res = both.run()
        assert [res[r] for r in rids] == solo

    @pytest.mark.parametrize("arch", PARITY_ARCHS)
    def test_token_for_token_vs_dense_engine(self, setups, arch):
        """Chunked-prefill + paged decode reproduce the seed engine's greedy
        outputs exactly, across heterogeneous prompt lengths."""
        cfg, params = setups[arch]
        paged = ServingEngine(cfg, params, ServeConfig(
            slots=2, max_len=64, block_size=8, prefill_chunk=16))
        dense = DenseServingEngine(cfg, params, ServeConfig(slots=2, max_len=64))
        prompts = _prompts(cfg, 5)
        pr = [paged.submit(p, max_new_tokens=5) for p in prompts]
        dr = [dense.submit(p, max_new_tokens=5) for p in prompts]
        pres, dres = paged.run(), dense.run()
        for a, b in zip(pr, dr):
            assert pres[a] == dres[b]

    def test_single_token_request_parity(self, setups):
        """max_new_tokens=1 finishes on the prefill-sampled token in BOTH
        engines (the dense engine used to decode one extra)."""
        cfg, params = setups["qwen1.5-0.5b"]
        paged = ServingEngine(cfg, params, ServeConfig(
            slots=2, max_len=64, block_size=8, prefill_chunk=16))
        dense = DenseServingEngine(cfg, params, ServeConfig(slots=2, max_len=64))
        p = _prompts(cfg, 1)[0]
        pr, dr = paged.submit(p, 1), dense.submit(p, 1)
        pres, dres = paged.run(), dense.run()
        assert len(pres[pr]) == len(dres[dr]) == 1
        assert pres[pr] == dres[dr]

    def test_matches_manual_decode(self, setups):
        """Paged engine output == hand-rolled dense prefill+decode loop."""
        import jax.numpy as jnp
        cfg, params = setups["qwen1.5-0.5b"]
        prompt = [3, 1, 4, 1, 5]
        eng = ServingEngine(cfg, params, ServeConfig(slots=1, max_len=64,
                                                     block_size=8,
                                                     prefill_chunk=8))
        rid = eng.submit(prompt, max_new_tokens=4)
        got = eng.run()[rid]

        toks = jnp.asarray([prompt], jnp.int32)
        logits, caches = tf.prefill(params, cfg, {"tokens": toks}, max_len=64)
        expect = [int(jnp.argmax(logits[0, -1]))]
        pos = len(prompt)
        for _ in range(3):
            logits, caches = tf.decode_step(
                params, cfg, jnp.asarray([[expect[-1]]], jnp.int32), caches, pos)
            expect.append(int(jnp.argmax(logits[0, -1])))
            pos += 1
        assert got == expect


class TestBoundedCompilation:
    def test_two_step_shapes_regardless_of_prompt_lengths(self, setups):
        """The re-jit fix: any mix of prompt lengths compiles exactly one
        chunked-prefill shape and one decode shape.  (The seed engine traced
        prefill once per distinct length — asserted as the contrast.)"""
        cfg, params = setups["qwen1.5-0.5b"]
        paged = ServingEngine(cfg, params, ServeConfig(
            slots=2, max_len=64, block_size=8, prefill_chunk=16))
        lengths = (3, 5, 7, 9, 11, 14, 17, 21)
        for p in _prompts(cfg, len(lengths), lengths):
            paged.submit(p, max_new_tokens=3)
        paged.run()
        assert paged.trace_counts == {"prefill_chunk": 1, "decode": 1,
                                      "verify": 0}

        dense = DenseServingEngine(cfg, params, ServeConfig(slots=2, max_len=64))
        for p in _prompts(cfg, len(lengths), lengths):
            dense.submit(p, max_new_tokens=3)
        dense.run()
        assert dense.trace_counts["prefill"] == len(lengths)

    def test_flatness_beats_dense_engine(self, setups):
        cfg, params = setups["qwen1.5-0.5b"]
        serve = ServeConfig(slots=2, max_len=64, block_size=8, prefill_chunk=16)
        paged = ServingEngine(cfg, params, serve)
        dense = DenseServingEngine(cfg, params, serve)
        for eng in (paged, dense):
            for p in _prompts(cfg, 5, (20, 17, 22, 19, 21)):
                eng.submit(p, max_new_tokens=4)
            eng.run()
        assert paged.flatness_cov() < dense.flatness_cov()


class TestSampling:
    def test_temperature_stream_is_reproducible(self, setups):
        """Identical request streams + same ServeConfig.seed => identical
        outputs (per-lane keys fold (seed, rid, token_idx) — no shared
        state), regardless of slot count / interleaving."""
        cfg, params = setups["qwen1.5-0.5b"]
        prompts = _prompts(cfg, 4)

        def run(slots, seed):
            eng = ServingEngine(cfg, params, ServeConfig(
                slots=slots, max_len=64, block_size=8, prefill_chunk=16,
                temperature=0.8, seed=seed))
            rids = [eng.submit(p, max_new_tokens=5) for p in prompts]
            res = eng.run()
            return [res[r] for r in rids]

        assert run(2, seed=7) == run(2, seed=7)
        # lane assignment / batching must not leak into sampling
        assert run(2, seed=7) == run(4, seed=7)
        assert run(2, seed=7) != run(2, seed=8)

    def test_eos_stops_lane(self, setups):
        cfg, params = setups["qwen1.5-0.5b"]
        eng = ServingEngine(cfg, params, ServeConfig(
            slots=1, max_len=64, block_size=8, prefill_chunk=16))
        rid = eng.submit([1, 2, 3], max_new_tokens=8)
        greedy = eng.run()[rid]
        eos = greedy[1]
        eng2 = ServingEngine(cfg, params, ServeConfig(
            slots=1, max_len=64, block_size=8, prefill_chunk=16,
            eos_token=eos))
        rid2 = eng2.submit([1, 2, 3], max_new_tokens=8)
        out = eng2.run()[rid2]
        # seed-engine semantics: eos is included, lane stops at its first
        # occurrence in the greedy stream
        assert out == greedy[: greedy.index(eos) + 1]


class TestBlockPressure:
    def test_preemption_resume_preserves_greedy_outputs(self, setups):
        """A pool too small for both lanes forces preempt + recompute-resume;
        outputs still match the unconstrained engine token-for-token."""
        cfg, params = setups["qwen1.5-0.5b"]
        # r0 grows from 1 block (5-token prompt) to 3 blocks over 12 decode
        # steps; r1 holds 2 blocks — a 3-block pool forces r0's growth to
        # evict r1 mid-flight, which then resumes by recompute
        prompts = _prompts(cfg, 2, (5, 9))
        max_new = (12, 4)
        big = ServingEngine(cfg, params, ServeConfig(
            slots=2, max_len=64, block_size=8, prefill_chunk=8))
        br = [big.submit(p, max_new_tokens=n) for p, n in zip(prompts, max_new)]
        bres = big.run()

        tight = ServingEngine(cfg, params, ServeConfig(
            slots=2, max_len=64, block_size=8, prefill_chunk=8,
            num_blocks=4))   # 3 allocatable blocks = 24 token-slots shared
        tr = [tight.submit(p, max_new_tokens=n) for p, n in zip(prompts, max_new)]
        tres = tight.run()
        assert [tres[r] for r in tr] == [bres[r] for r in br]
        assert any(m["preempted"] for m in tight.metrics)

    def test_pool_too_small_raises(self, setups):
        cfg, params = setups["qwen1.5-0.5b"]
        eng = ServingEngine(cfg, params, ServeConfig(
            slots=1, max_len=64, block_size=8, prefill_chunk=8,
            num_blocks=2))   # 1 allocatable block < one 16-token context
        eng.submit([1, 2, 3, 4, 5, 6, 7, 8, 9], max_new_tokens=4)
        with pytest.raises(RuntimeError):
            eng.run()

    def test_defragment_mid_stream_is_transparent(self, setups):
        cfg, params = setups["qwen1.5-0.5b"]
        prompts = _prompts(cfg, 3, (9, 5, 13))

        def run(defrag):
            eng = ServingEngine(cfg, params, ServeConfig(
                slots=2, max_len=64, block_size=8, prefill_chunk=8))
            rids = [eng.submit(p, max_new_tokens=6) for p in prompts]
            steps = 0
            while eng.pending and steps < 500:
                eng.step()
                steps += 1
                if defrag and steps % 3 == 0:
                    eng.defragment()
            res = eng._results
            return [res[r] for r in rids]

        assert run(defrag=True) == run(defrag=False)


class TestEngineSelection:
    def test_recurrent_arch_falls_back_to_dense_engine(self):
        cfg = registry.get_config("xlstm-1.3b", smoke=True)
        params = tf.init_params(cfg, jax.random.PRNGKey(0))
        with pytest.raises(ValueError):
            ServingEngine(cfg, params, ServeConfig(slots=1, max_len=32))
        eng = make_engine(cfg, params, ServeConfig(slots=1, max_len=32))
        assert isinstance(eng, DenseServingEngine)
        rid = eng.submit([1, 2, 3], max_new_tokens=3)
        assert len(eng.run()[rid]) == 3

    def test_attention_arch_gets_paged_engine(self, setups):
        cfg, params = setups["qwen1.5-0.5b"]
        eng = make_engine(cfg, params, ServeConfig(slots=1, max_len=32))
        assert isinstance(eng, ServingEngine)

    def test_metrics_exported(self, setups):
        cfg, params = setups["qwen1.5-0.5b"]
        eng = ServingEngine(cfg, params, ServeConfig(
            slots=2, max_len=64, block_size=8, prefill_chunk=16))
        eng.submit([1, 2, 3, 4, 5], max_new_tokens=4)
        eng.run()
        assert eng.metrics
        keys = {"step", "tokens", "prefill_tokens", "decode_tokens",
                "blocks_in_use", "free_blocks", "queue_depth", "preempted",
                "hbm_bytes"}
        assert keys <= set(eng.metrics[0])
        assert all(m["hbm_bytes"] > 0 for m in eng.metrics)


class TestWindowReclamation:
    """Sliding-window block reclamation: an all-window (gemma3-local-style)
    stack frees blocks that fall behind the window, so blocks_in_use
    plateaus instead of growing with context — without changing outputs."""

    @pytest.fixture(scope="class")
    def allwin(self):
        cfg = registry.get_config("gemma3-12b", smoke=True).with_(
            pattern=("dense:window",) * 6)    # drop the global layers
        return cfg, tf.init_params(cfg, jax.random.PRNGKey(0))

    def test_blocks_plateau(self, allwin):
        cfg, params = allwin
        eng = ServingEngine(cfg, params, ServeConfig(
            slots=1, max_len=64, block_size=8, prefill_chunk=8))
        assert eng.window_horizon == cfg.window_size == 16
        rid = eng.submit(list(range(1, 9)), max_new_tokens=40)
        out = eng.run()[rid]
        assert len(out) == 40
        peak = max(m["blocks_in_use"] for m in eng.metrics)
        # 48-token context = 6 blocks unreclaimed; window 16 needs <= 3 live
        # (2 visible + the write block)
        assert peak <= 3

    def test_outputs_match_dense_engine(self, allwin):
        """Reclamation must be invisible: the dense engine's ring-buffer
        window cache is the oracle."""
        cfg, params = allwin
        paged = ServingEngine(cfg, params, ServeConfig(
            slots=2, max_len=64, block_size=8, prefill_chunk=16))
        dense = DenseServingEngine(cfg, params, ServeConfig(slots=2, max_len=64))
        prompts = _prompts(cfg, 4, (4, 9, 13, 21))
        pr = [paged.submit(p, max_new_tokens=20) for p in prompts]
        dr = [dense.submit(p, max_new_tokens=20) for p in prompts]
        pres, dres = paged.run(), dense.run()
        assert [pres[a] for a in pr] == [dres[b] for b in dr]
        assert any(m["blocks_in_use"] for m in paged.metrics)

    def test_full_attention_layer_disables_reclamation(self, setups):
        """gemma3 proper keeps its global layers -> shared tables cannot be
        reclaimed; qwen (no window at all) likewise."""
        for arch in ("gemma3-12b", "qwen1.5-0.5b"):
            cfg, params = setups[arch]
            eng = ServingEngine(cfg, params, ServeConfig(slots=1, max_len=64))
            assert eng.window_horizon is None


class TestAttnReadMetrics:
    def test_gather_vs_stream_bytes_exported(self, setups):
        cfg, params = setups["qwen1.5-0.5b"]
        eng = ServingEngine(cfg, params, ServeConfig(
            slots=2, max_len=64, block_size=8, prefill_chunk=16))
        eng.submit([1, 2, 3, 4, 5], max_new_tokens=4)
        eng.run()
        assert eng.paged_attn_mode in ("ref", "pallas", "interpret")
        for m in eng.metrics:
            assert m["attn_bytes_gather"] >= m["attn_bytes_stream"] > 0

    def test_paged_attn_kernel_override_threads_through(self, setups):
        """ServeConfig.paged_attn_kernel overrides cfg, token streams are
        unchanged, and the two jitted step shapes stay at two."""
        cfg, params = setups["qwen1.5-0.5b"]
        prompts = _prompts(cfg, 3, (4, 9, 13))

        def run(mode):
            eng = ServingEngine(cfg, params, ServeConfig(
                slots=2, max_len=64, block_size=8, prefill_chunk=16,
                paged_attn_kernel=mode))
            rids = [eng.submit(p, max_new_tokens=4) for p in prompts]
            res = eng.run()
            return [res[r] for r in rids], eng

        ref_streams, ref_eng = run("ref")
        ker_streams, ker_eng = run("interpret")
        assert ref_eng.paged_attn_mode == "ref"
        assert ker_eng.paged_attn_mode == "interpret"
        assert ker_streams == ref_streams
        assert ker_eng.trace_counts == {"prefill_chunk": 1, "decode": 1,
                                        "verify": 0}
