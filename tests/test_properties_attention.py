"""Hypothesis property tests on attention invariants across random
geometries — the ring-buffer SWA cache and chunk schedules especially."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models import attention as A
from repro.models.layers import init_from_specs


def _roundtrip(cfg, S, B, key):
    p = init_from_specs(A.attn_specs(cfg), key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, cfg.d_model)) * 0.5
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    full = A.gqa_forward(p, cfg, x, pos)
    half = S // 2
    _, cache = A.gqa_prefill(p, cfg, x[:, :half], pos[:, :half], max_len=S)
    outs = []
    for t in range(half, S):
        o, cache = A.gqa_decode(p, cfg, x[:, t:t + 1], cache, t)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, half:]),
                               rtol=3e-4, atol=3e-4)


class TestDecodeEquivalenceProperty:
    @given(st.sampled_from([(4, 2), (4, 4), (6, 3), (8, 2)]),
           st.sampled_from([8, 12, 16, 24]),
           st.sampled_from([None, 3, 4, 6, 8]),
           st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=12, deadline=None)
    def test_ring_cache_decode_equals_forward(self, heads, S, window, seed):
        """Teacher-forced decode through the (possibly ring) KV cache must
        reproduce the parallel forward for arbitrary (H, KVH, S, window)."""
        H, KVH = heads
        cfg = A.AttnConfig(d_model=H * 8, num_heads=H, num_kv_heads=KVH,
                           head_dim=8, window=window, dtype=jnp.float32)
        _roundtrip(cfg, S, B=2, key=jax.random.PRNGKey(seed))

    @given(st.integers(1, 64), st.integers(1, 512), st.integers(0, 600))
    @settings(max_examples=60)
    def test_ring_slot_positions_consistent(self, span, window, pos):
        """The ring-buffer position reconstruction in gqa_decode: entry j
        holds the latest absolute position p' <= pos with p' % span == j."""
        j = np.arange(span)
        kpos_abs = pos - ((pos - j) % span)
        assert ((kpos_abs % span) == j).all()
        assert (kpos_abs <= pos).all()
        assert (kpos_abs > pos - span).all()


class TestMaskProperties:
    @given(st.integers(1, 32), st.integers(1, 48), st.integers(0, 64),
           st.sampled_from([None, 1, 4, 16]))
    @settings(max_examples=60, deadline=None)
    def test_causal_mask_semantics(self, S, T, off, window):
        m = np.asarray(A.causal_mask(S, T, off, window))
        for i in range(S):
            for t in range(T):
                vis = t <= off + i
                if window is not None:
                    vis = vis and t > off + i - window
                assert m[i, t] == vis, (i, t, off, window)

    @given(st.integers(2, 6), st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=8, deadline=None)
    def test_kv_chunk_size_invariance(self, nchunks, seed):
        """_sdpa_kv_chunked must be exact for any chunk divisor."""
        key = jax.random.PRNGKey(seed)
        S = 24
        q = jax.random.normal(key, (2, S, 4, 8))
        k = jax.random.normal(jax.random.fold_in(key, 1), (2, S, 2, 8))
        v = jax.random.normal(jax.random.fold_in(key, 2), (2, S, 2, 8))
        ref = A._sdpa(q, k, v, A.causal_mask(S, S), 0.35)
        if S % nchunks:
            return
        got = A._sdpa_kv_chunked(q, k, v, 0.35, chunk=S // nchunks)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
