"""Substrate tests: data pipeline determinism, optimizers, fault helpers."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, TokenPipeline

# repro.dist is a planned subsystem not present in every checkout — skip the
# fault-helper tests (not the whole module) when it is missing.
try:
    from repro.dist.fault import StepWatchdog, run_with_retries
    HAVE_FAULT = True
except ModuleNotFoundError:
    HAVE_FAULT = False
    StepWatchdog = run_with_retries = None
from repro.models import registry
from repro.optim import adafactor as adaf
from repro.optim import adamw as adam


def tiny_cfg():
    return registry.get_config("qwen1.5-0.5b", smoke=True)


class TestDataPipeline:
    def test_deterministic_across_restart(self):
        """batch_at(i) must be identical for a fresh pipeline (fault resume)."""
        cfg = tiny_cfg()
        d = DataConfig(seed=7, batch=4, seq_len=16)
        p1 = TokenPipeline(cfg, d)
        p2 = TokenPipeline(cfg, d)
        for step in (0, 3, 1000):
            b1, b2 = p1.batch_at(step), p2.batch_at(step)
            np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
            np.testing.assert_array_equal(b1["labels"], b2["labels"])

    def test_shards_are_disjoint_streams(self):
        cfg = tiny_cfg()
        a = TokenPipeline(cfg, DataConfig(seed=7, batch=4, seq_len=16,
                                          shard_index=0, num_shards=2))
        b = TokenPipeline(cfg, DataConfig(seed=7, batch=4, seq_len=16,
                                          shard_index=1, num_shards=2))
        assert not np.array_equal(a.batch_at(0)["tokens"], b.batch_at(0)["tokens"])

    def test_prefetch_iterator_matches_direct(self):
        cfg = tiny_cfg()
        d = DataConfig(seed=3, batch=2, seq_len=8, prefetch=2)
        pipe = TokenPipeline(cfg, d).start(0)
        try:
            got = [next(pipe) for _ in range(3)]
        finally:
            pipe.stop()
        for i, b in enumerate(got):
            np.testing.assert_array_equal(b["tokens"],
                                          TokenPipeline(cfg, d).batch_at(i)["tokens"])

    def test_labels_are_next_tokens(self):
        cfg = tiny_cfg()
        b = TokenPipeline(cfg, DataConfig(batch=2, seq_len=8)).batch_at(0)
        # same underlying sequence shifted by one
        assert b["tokens"].shape == b["labels"].shape

    def test_modality_stubs(self):
        mg = registry.get_config("musicgen-large", smoke=True)
        b = TokenPipeline(mg, DataConfig(batch=2, seq_len=8)).batch_at(0)
        assert "embeds" in b and b["embeds"].shape == (2, 8, mg.d_model)
        vl = registry.get_config("llama-3.2-vision-11b", smoke=True)
        b = TokenPipeline(vl, DataConfig(batch=2, seq_len=8)).batch_at(0)
        assert b["enc"].shape == (2, vl.encoder_tokens, vl.d_model)


class TestOptimizers:
    def _quadratic(self, params):
        return sum(jnp.sum(p.astype(jnp.float32) ** 2) for p in jax.tree.leaves(params))

    def test_adamw_converges_on_quadratic(self):
        params = {"w": jnp.ones((8, 8)), "b": jnp.ones((8,))}
        cfg = adam.AdamWConfig(lr=0.1, weight_decay=0.0)
        state = adam.adamw_init(params)
        for _ in range(60):
            g = jax.grad(self._quadratic)(params)
            params, state, _ = adam.adamw_update(cfg, g, state, params)
        assert float(self._quadratic(params)) < 0.1  # from 72.0 at init

    def test_adamw_clipping(self):
        params = {"w": jnp.ones((4,))}
        cfg = adam.AdamWConfig(lr=1e-3, clip_norm=1.0)
        state = adam.adamw_init(params)
        g = {"w": jnp.full((4,), 1e6)}
        _, _, metrics = adam.adamw_update(cfg, g, state, params)
        assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip

    def test_adafactor_converges_and_state_is_small(self):
        params = {"w": jnp.ones((32, 16))}
        cfg = adaf.AdafactorConfig(lr=0.3)
        state = adaf.adafactor_init(params)
        n_state = sum(np.prod(l.shape) for l in jax.tree.leaves(state["factors"]))
        assert n_state == 32 + 16  # factored: r + c, not r*c
        for _ in range(80):
            g = jax.grad(self._quadratic)(params)
            params, state, _ = adaf.adafactor_update(cfg, g, state, params)
        assert float(self._quadratic(params)) < 1.0

    def test_adafactor_specs_match_init(self):
        params = {"w": jnp.ones((8, 4)), "v": jnp.ones((5,))}
        specs = adaf.adafactor_state_specs(
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params))
        state = adaf.adafactor_init(params)
        assert (jax.tree.map(lambda s: s.shape, specs)
                == jax.tree.map(lambda a: a.shape, state))


@pytest.mark.skipif(not HAVE_FAULT, reason="repro.dist.fault not present")
class TestFault:
    def test_retry_recovers(self):
        calls = {"n": 0}

        def flaky():
            calls["n"] += 1
            if calls["n"] < 3:
                raise RuntimeError("transient")
            return "ok"

        assert run_with_retries(flaky, retries=5, sleep=lambda s: None) == "ok"
        assert calls["n"] == 3

    def test_retry_exhausts(self):
        def always():
            raise RuntimeError("hard")
        with pytest.raises(RuntimeError):
            run_with_retries(always, retries=2, sleep=lambda s: None)

    def test_watchdog_flags_straggler(self):
        wd = StepWatchdog(threshold=2.0)
        for _ in range(10):
            assert not wd.record(1.0)
        assert wd.record(5.0)
        assert not wd.record(1.1)
