"""End-to-end behaviour tests for the paper's system.

The paper's pipeline in one place: analytic design (Eqs 3-4) → schedule
construction → cycle-accurate execution → the same planner driving the JAX
streamer and the Pallas kernel's ring depth.  Plus a micro training run
proving the full stack (data → model → optimizer → checkpoint) descends.
"""
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.core.analytical as ana
from repro.core import schedule as sched
from repro.core import simulator as sim
from repro.core.analytical import PimConfig
from repro.core.schedule import plan_stream


class TestPaperPipelineEndToEnd:
    def test_design_to_execution(self):
        """Size an accelerator for a bandwidth budget (Eq 4), build the GPP
        schedule, execute it in the DES: bandwidth ~saturated, macros ~always
        busy, and throughput within ramp-overhead of the analytic optimum."""
        cfg = PimConfig(band=128.0, s=4.0).with_(n_in=24)  # t_pim:t_rw = 3:1
        n = round(ana.num_macros(cfg, "gpp"))
        rounds = 32
        res = sim.simulate("gpp", cfg, n, rounds)
        assert res.bandwidth_utilization > 0.95
        assert res.macro_utilization > 0.9
        ideal = rounds * (cfg.time_pim + cfg.time_rewrite)
        assert res.total_cycles < ideal * 1.1  # ramp only

    def test_planner_consistency_kernel_vs_streamer(self):
        """One planner (plan_stream) drives both levels: ring depth must be
        monotone in the transfer/compute ratio everywhere."""
        depths = [
            plan_stream(block_bytes=1e6, compute_flops=f,
                        flops_per_s=197e12, transfer_bytes_per_s=819e9).ring_depth
            for f in (1e4, 1e6, 1e8, 1e10)
        ]
        assert depths == sorted(depths, reverse=True)
        assert depths[-1] == 2  # compute-bound -> plain double buffering

    def test_schedule_ir_replays_in_simulator(self):
        """The idealized schedule's makespan matches the DES when bandwidth
        is unconstrained (the IR and the machine agree)."""
        cfg = PimConfig(band=1e9, s=4.0).with_(n_in=24)
        s = sched.build("gpp", cfg, 6, 5)
        r = sim.simulate("gpp", cfg, 6, 5)
        assert r.total_cycles == pytest.approx(s.makespan, rel=1e-6)


class TestTrainingEndToEnd:
    def test_micro_train_descends_and_resumes(self, tmp_path):
        """Full stack on CPU: synthetic pipeline -> reduced model -> AdamW ->
        checkpoint -> resume -> loss strictly below init."""
        from repro.checkpoint.manager import CheckpointManager
        from repro.data.pipeline import DataConfig, TokenPipeline
        from repro.models import registry
        from repro.models import transformer as tf
        from repro.optim import adamw

        cfg = registry.get_config("qwen1.5-0.5b", smoke=True)
        data = DataConfig(seed=0, batch=4, seq_len=32)
        pipe = TokenPipeline(cfg, data)
        params = tf.init_params(cfg, jax.random.PRNGKey(0))
        opt_state = adamw.adamw_init(params)
        optc = adamw.AdamWConfig(lr=1e-3)

        @jax.jit
        def step(params, opt_state, batch):
            loss, g = jax.value_and_grad(
                lambda p: tf.loss_fn(p, cfg, batch))(params)
            params, opt_state, _ = adamw.adamw_update(optc, g, opt_state, params)
            return params, opt_state, loss

        losses = []
        for i in range(8):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
            params, opt_state, loss = step(params, opt_state, batch)
            losses.append(float(loss))
        assert losses[-1] < losses[0]

        mgr = CheckpointManager(str(tmp_path))
        mgr.save(8, {"p": params, "o": opt_state})
        restored, s8 = mgr.restore({"p": params, "o": opt_state})
        assert s8 == 8
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(8).items()}
        _, _, l1 = step(restored["p"], restored["o"], batch)
        _, _, l2 = step(params, opt_state, batch)
        assert float(l1) == pytest.approx(float(l2), rel=1e-6)


class TestKernelSystemIntegration:
    def test_kernel_ring_depth_from_paper_model(self):
        """The kernel's auto ring depth equals ceil(t_dma/t_cmp)+1 from the
        paper's timing model with TPU constants."""
        from repro.kernels.ops import HBM_BYTES_PER_S, PEAK_FLOPS, plan_ring_depth
        for M in (8, 64, 512):
            K = bn = 256
            t_dma = (K * bn * 2) / HBM_BYTES_PER_S
            t_cmp = (2 * M * K * bn) / PEAK_FLOPS
            expect = min(8, max(2, math.ceil(t_dma / t_cmp) + 1))
            assert plan_ring_depth(M, K, bn) == expect

    def test_streamed_sequence_is_paper_workload(self):
        """The consecutive-GeMM BLAS workload (paper §V-A) through the
        streaming kernel, weights re-streamed per round."""
        from repro.kernels.ops import streamed_gemm_sequence
        from repro.kernels.ref import streamed_gemm_seq_ref
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (8, 128), jnp.float32)
        ws = jax.random.normal(key, (4, 128, 256), jnp.float32)
        ys = streamed_gemm_sequence(x, ws, block_n=128, num_bufs=3,
                                    interpret=True)
        np.testing.assert_allclose(np.asarray(ys),
                                   np.asarray(streamed_gemm_seq_ref(x, ws)),
                                   rtol=1e-5, atol=1e-4)
