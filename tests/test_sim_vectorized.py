"""Vectorized GPP discrete-event simulator vs the scalar reference loop.

The numpy path (`simulate_gpp`) must reproduce the scalar event loop
(`simulate_gpp_scalar`) result-for-result: both integrate the same
piecewise-constant-rate system, so every SimResult total agrees to float
round-off, across compute-bound, balanced and DMA-bound configs, odd macro
counts that straddle the stagger groups, and multi-round workloads.
"""
import time

import pytest

from repro.core.analytical import PimConfig
from repro.core.simulator import simulate, simulate_gpp, simulate_gpp_scalar

FIELDS = ("total_cycles", "compute_cycles", "rewrite_cycles",
          "bytes_transferred", "peak_bandwidth", "bw_busy_cycles")


def assert_same(a, b, ctx):
    for f in FIELDS:
        va, vb = getattr(a, f), getattr(b, f)
        assert abs(va - vb) <= 1e-9 * max(1.0, abs(vb)), (ctx, f, va, vb)


@pytest.mark.parametrize("n_in", [1.0, 2.0, 8.0, 24.0])
@pytest.mark.parametrize("num_macros", [1, 3, 7, 64, 130])
def test_vectorized_matches_scalar(n_in, num_macros):
    cfg = PimConfig().with_(n_in=n_in)
    a = simulate_gpp(cfg, num_macros, 4)
    b = simulate_gpp_scalar(cfg, num_macros, 4)
    assert_same(a, b, (n_in, num_macros))


def test_vectorized_matches_scalar_band_limited():
    """Arbiter-saturated regime: bus rate < per-macro s, many rewriters."""
    cfg = PimConfig(band=16.0, s=4.0).with_(n_in=4.0)
    assert_same(simulate_gpp(cfg, 96, 6), simulate_gpp_scalar(cfg, 96, 6),
                "band_limited")


def test_dispatch_uses_vectorized():
    assert simulate.__module__ == simulate_gpp.__module__
    cfg = PimConfig()
    assert_same(simulate("gpp", cfg, 33, 3), simulate_gpp_scalar(cfg, 33, 3),
                "dispatch")


def test_vectorized_is_faster_at_scale():
    """The point of the rewrite: per-event work is numpy kernels, not Python
    loops, so >=1024-macro sweeps stop being quadratic in Python.  Best-of-3
    each and a plain faster-than bar (measured ~10x) so a scheduling stall on
    a loaded CI worker can't flip the comparison."""
    cfg = PimConfig()

    def best_of(fn, n=3):
        best = float("inf")
        for _ in range(n):
            t0 = time.perf_counter()
            fn(cfg, 1024, 2)
            best = min(best, time.perf_counter() - t0)
        return best

    t_vec = best_of(simulate_gpp)
    t_sca = best_of(simulate_gpp_scalar)
    assert t_vec < t_sca, (t_vec, t_sca)
