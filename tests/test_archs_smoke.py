"""Per-architecture smoke tests: reduced config, one train step + one decode
step on CPU, asserting output shapes and finite values (assignment req (f))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, cells_for
from repro.models import registry
from repro.models import transformer as tf

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def make_batch(cfg, key=KEY, batch=B, seq=S):
    b = {"labels": jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)}
    if cfg.input_mode == "tokens":
        b["tokens"] = jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)
    else:
        b["embeds"] = jax.random.normal(key, (batch, seq, cfg.d_model), jnp.float32)
    if cfg.encoder_tokens:
        b["enc"] = jax.random.normal(key, (batch, cfg.encoder_tokens, cfg.d_model),
                                     jnp.float32)
    return b


@pytest.mark.parametrize("arch", registry.ARCH_NAMES)
class TestArchSmoke:
    def test_train_step(self, arch):
        cfg = registry.get_config(arch, smoke=True)
        params = tf.init_params(cfg, KEY)
        batch = make_batch(cfg)
        loss, grads = jax.jit(jax.value_and_grad(
            lambda p: tf.loss_fn(p, cfg, batch)))(params)
        assert np.isfinite(float(loss))
        for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
            assert np.isfinite(np.asarray(g, np.float32)).all(), path

    def test_forward_shapes(self, arch):
        cfg = registry.get_config(arch, smoke=True)
        params = tf.init_params(cfg, KEY)
        batch = make_batch(cfg)
        logits = jax.jit(lambda p: tf.forward(p, cfg, batch))(params)
        assert logits.shape == (B, S, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all()

    def test_prefill_decode(self, arch):
        cfg = registry.get_config(arch, smoke=True)
        params = tf.init_params(cfg, KEY)
        batch = make_batch(cfg)
        logits, caches = jax.jit(
            lambda p, b: tf.prefill(p, cfg, b, max_len=S + 4))(params, batch)
        assert logits.shape == (B, 1, cfg.vocab_size)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        inp = (tok if cfg.input_mode == "tokens"
               else jax.random.normal(KEY, (B, 1, cfg.d_model)))
        lg, caches2 = jax.jit(
            lambda p, t, c: tf.decode_step(p, cfg, t, c, S,
                                           enc=batch.get("enc")))(params, inp, caches)
        assert lg.shape == (B, 1, cfg.vocab_size)
        assert np.isfinite(np.asarray(lg)).all()
        # cache structure preserved
        assert jax.tree.structure(caches) == jax.tree.structure(caches2)

    def test_param_specs_match_init(self, arch):
        cfg = registry.get_config(arch, smoke=True)
        specs = tf.param_specs(cfg)
        params = tf.init_params(cfg, KEY)
        spec_shapes = jax.tree.map(lambda s: s.shape, specs)
        got_shapes = jax.tree.map(lambda a: a.shape, params)
        assert spec_shapes == got_shapes


class TestAssignment:
    def test_full_configs_match_assignment(self):
        """Spot-check the literal assigned hyperparameters."""
        expect = {
            "xlstm-1.3b": dict(num_layers=48, d_model=2048, num_heads=4, d_ff=0,
                               vocab_size=50304),
            "kimi-k2-1t-a32b": dict(num_layers=61, d_model=7168, num_heads=64,
                                    num_kv_heads=8, moe_d_ff=2048,
                                    vocab_size=163840, num_experts=384,
                                    experts_per_token=8),
            "deepseek-v2-lite-16b": dict(num_layers=27, d_model=2048,
                                         num_heads=16, moe_d_ff=1408,
                                         vocab_size=102400, num_experts=64,
                                         experts_per_token=6, kv_lora_rank=512),
            "h2o-danube-1.8b": dict(num_layers=24, d_model=2560, num_heads=32,
                                    num_kv_heads=8, d_ff=6912, vocab_size=32000),
            "gemma3-12b": dict(num_layers=48, d_model=3840, num_heads=16,
                               num_kv_heads=8, d_ff=15360, vocab_size=262144),
            "qwen2-7b": dict(num_layers=28, d_model=3584, num_heads=28,
                             num_kv_heads=4, d_ff=18944, vocab_size=152064,
                             qkv_bias=True),
            "qwen1.5-0.5b": dict(num_layers=24, d_model=1024, num_heads=16,
                                 num_kv_heads=16, d_ff=2816, vocab_size=151936,
                                 qkv_bias=True),
            "musicgen-large": dict(num_layers=48, d_model=2048, num_heads=32,
                                   num_kv_heads=32, d_ff=8192, vocab_size=2048),
            "llama-3.2-vision-11b": dict(num_layers=40, d_model=4096,
                                         num_heads=32, num_kv_heads=8,
                                         d_ff=14336, vocab_size=128256),
            "zamba2-2.7b": dict(num_layers=54, d_model=2560, num_heads=32,
                                d_ff=10240, vocab_size=32000, ssm_state_dim=64),
        }
        for name, fields in expect.items():
            cfg = registry.get_config(name)
            for k, v in fields.items():
                assert getattr(cfg, k) == v, (name, k, getattr(cfg, k), v)

    def test_cell_assignment(self):
        """34 dry-run cells: long_500k only for the 4 sub-quadratic archs."""
        total = 0
        longs = []
        for name in registry.ARCH_NAMES:
            cfg = registry.get_config(name)
            cells = cells_for(cfg)
            total += len(cells)
            if "long_500k" in cells:
                longs.append(name)
        assert total == 34
        assert sorted(longs) == sorted(
            ["xlstm-1.3b", "zamba2-2.7b", "h2o-danube-1.8b", "gemma3-12b"])

    def test_param_count_sanity(self):
        """Full configs land near their published sizes."""
        cfg = registry.get_config("kimi-k2-1t-a32b")
        assert 0.9e12 < cfg.total_params() < 1.15e12
        assert 30e9 < cfg.active_params() < 40e9
        assert 14e9 < registry.get_config("deepseek-v2-lite-16b").total_params() < 17e9
        assert 7e9 < registry.get_config("qwen2-7b").total_params() < 8.2e9
