"""Unit + property tests for the paper's analytic model (Eqs 1-9)."""
import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import analytical as ana
from repro.core.analytical import PimConfig

PAPER_CFG = PimConfig(size_macro=32 * 32, size_ou=4 * 8, s=4.0)  # paper Fig 4 setup


class TestFig4:
    """Fig 4: naive ping-pong utilization peaks at n_in=8 for the paper config."""

    def test_peak_at_matched_point(self):
        c = PAPER_CFG.with_(n_in=8)
        assert c.time_pim == c.time_rewrite
        assert ana.naive_pp_macro_util(c) == pytest.approx(1.0)

    @pytest.mark.parametrize("n_in,util", [(1, 0.5625), (2, 0.625), (4, 0.75),
                                           (8, 1.0), (16, 0.75), (32, 0.625), (64, 0.5625)])
    def test_curve_values(self, n_in, util):
        assert ana.naive_pp_macro_util(PAPER_CFG.with_(n_in=n_in)) == pytest.approx(util)

    @given(st.floats(0.25, 512))
    def test_symmetry_in_ratio(self, n_in):
        """util(ratio) == util(1/ratio): Eqs 1-2 are symmetric around t_pim==t_rw."""
        c = PAPER_CFG.with_(n_in=n_in)
        c_inv = PAPER_CFG.with_(n_in=PAPER_CFG.size_ou**2 / (PAPER_CFG.s**2 * n_in))
        assert math.isclose(c.ratio, 1.0 / c_inv.ratio, rel_tol=1e-9)
        assert ana.naive_pp_macro_util(c) == pytest.approx(ana.naive_pp_macro_util(c_inv))

    @given(st.floats(0.01, 1e4))
    def test_bounded(self, n_in):
        u = ana.naive_pp_macro_util(PAPER_CFG.with_(n_in=n_in))
        assert 0.5 < u <= 1.0


class TestEq34:
    def test_insitu_count(self):
        c = PimConfig(band=128, s=4)
        assert ana.num_macros(c, "insitu") == 32

    def test_naive_doubles_insitu(self):
        c = PimConfig(band=128, s=4)
        assert ana.num_macros(c, "naive_pp") == 2 * ana.num_macros(c, "insitu")

    @given(st.floats(0.5, 256), st.floats(1, 8), st.floats(16, 1024))
    def test_gpp_dominates(self, n_in, s, band):
        """GPP supports >= as many macros as naive pp iff t_pim >= t_rw."""
        c = PimConfig(n_in=n_in, s=s, band=band)
        g, n = ana.num_macros(c, "gpp"), ana.num_macros(c, "naive_pp")
        if c.time_pim >= c.time_rewrite:
            assert g >= n * (1 - 1e-9)
        else:
            assert g <= n * (1 + 1e-9)

    @given(st.floats(0.5, 256), st.floats(1, 8))
    def test_gpp_bandwidth_exactly_saturated(self, n_in, s):
        """num_gpp * per-macro average demand == band (the design identity)."""
        c = PimConfig(n_in=n_in, s=s, band=128.0)
        total = ana.num_macros(c, "gpp") * ana.per_macro_bandwidth(c, "gpp")
        assert total == pytest.approx(c.band)


class TestEq56:
    def test_matched_point_equivalence(self):
        """At t_pim == t_rw naive and gpp coincide (paper §IV-B)."""
        c = PimConfig(n_in=PimConfig().size_ou / PimConfig().s)
        assert c.time_pim == pytest.approx(c.time_rewrite)
        g, i, n = ana.macro_count_ratio(c)
        assert g == pytest.approx(n)
        tg, ti, tn = ana.execution_time_ratio(c)
        assert tg == pytest.approx(tn)
        assert ti == pytest.approx(2.0 * tg)  # 2x over in-situ, as in Fig 6

    @given(st.floats(0.26, 250))
    def test_gpp_never_slower(self, n_in):
        tg, ti, tn = ana.execution_time_ratio(PimConfig(n_in=n_in))
        assert tg <= ti + 1e-9
        assert tg <= tn + 1e-9


class TestEq789:
    CFG = PimConfig(size_macro=1024, size_ou=32, s=8.0, n_in=4.0, band=512.0)

    def test_no_reduction_is_identity(self):
        assert ana.insitu_perf_degradation(self.CFG, 1.0) == pytest.approx(1.0)
        assert ana.naive_pp_perf_degradation(self.CFG, 1.0) == pytest.approx(1.0)
        assert ana.gpp_perf_degradation(self.CFG, 1.0) == pytest.approx(1.0)

    @pytest.mark.parametrize("n,expect", [(2, 0.7808), (4, 0.5931), (8, 0.4414),
                                          (16, 0.3237), (32, 0.2349), (64, 0.1691)])
    def test_eq9_matches_table2_theory(self, n, expect):
        """Eq 9 at the Table II design point reproduces the theory column."""
        assert ana.gpp_perf_degradation(self.CFG, n) == pytest.approx(expect, abs=2e-4)

    def test_paper_headline_5_38x(self):
        """At band/64, GPP retains 5.38x more perf than in-situ (paper §V-C)."""
        g = ana.gpp_perf_degradation(self.CFG, 64)
        i = ana.insitu_perf_degradation(self.CFG, 64)
        assert g / i == pytest.approx(5.49, abs=0.15)  # paper reports 5.38 (integer practice)

    @given(st.floats(1, 128))
    def test_gpp_retains_most(self, n):
        """GPP >= in-situ >= naive for all reductions (the paper's ordering)."""
        g = ana.gpp_perf_degradation(self.CFG, n)
        i = ana.insitu_perf_degradation(self.CFG, n)
        na = ana.naive_pp_perf_degradation(self.CFG, n)
        assert g >= i - 1e-9
        assert i >= na - 1e-9

    @given(st.floats(1, 128), st.floats(1.01, 4))
    def test_monotone_degradation(self, n, factor):
        for fn in (ana.insitu_perf_degradation, ana.naive_pp_perf_degradation,
                   ana.gpp_perf_degradation):
            assert fn(self.CFG, n * factor) <= fn(self.CFG, n) + 1e-9
