"""Unified dense() routing parity: every model projection now flows through
`kernels.ops.dense` / `dense_grouped` — these tests pin the refactor to the
seed einsum math.

Each *oracle* below is a line-for-line copy of the pre-refactor (seed)
einsum implementation of that block's projections.  With dense_mode="ref"
the refactored module must reproduce the oracle's forward outputs AND
gradients to f32 accumulation tolerance, for every model kind in
models/registry.py (mha, gqa, mla, moe, ssm, mlstm, slstm, cross-attn).

Also covered: the einsum-shaped projection adapter itself, interpret-mode
kernel parity for `dense_grouped` at a ragged expert-capacity shape, and
the TimingCache feedback into `plan_matmul_tiles`.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.schedule import (
    TimingCache, plan_matmul_tiles, set_default_timing_cache,
)
from repro.kernels.gpp_matmul import gpp_matmul_grouped
from repro.kernels.ops import dense, dense_grouped
from repro.kernels.ref import dense_grouped_ref
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import init_from_specs, rmsnorm, rope

pytestmark = pytest.mark.tier1

KEY = jax.random.PRNGKey(7)
B, S, D = 2, 16, 32
TOL = dict(rtol=2e-5, atol=2e-5)   # f32 accumulation tolerance
GTOL = dict(rtol=1e-4, atol=1e-5)


def init(specs, key=KEY):
    return init_from_specs(specs, key)


def seq_input(d=D, s=S, key=KEY):
    return jax.random.normal(key, (B, s, d), jnp.float32) * 0.5


def assert_fwd_and_grad(fn_new, fn_oracle, params, x):
    y_new, y_old = fn_new(params, x), fn_oracle(params, x)
    np.testing.assert_allclose(np.asarray(y_new), np.asarray(y_old), **TOL)
    g_new = jax.grad(lambda p: (fn_new(p, x) ** 2).mean())(params)
    g_old = jax.grad(lambda p: (fn_oracle(p, x) ** 2).mean())(params)
    for (path, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(g_new)[0],
            jax.tree_util.tree_flatten_with_path(g_old)[0]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   err_msg=str(path), **GTOL)


# ---------------------------------------------------------------------------
# the einsum-shaped projection adapter
# ---------------------------------------------------------------------------

class TestProjectionAdapter:
    def test_dhk_weight(self):
        x = seq_input()
        w = jax.random.normal(KEY, (D, 4, 8), jnp.float32)
        got = dense(x, w, mode="ref")
        want = jnp.einsum("bsd,dhk->bshk", x, w)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_hkd_weight_contract2(self):
        x = jax.random.normal(KEY, (B, S, 4, 8), jnp.float32)
        w = jax.random.normal(KEY, (4, 8, D), jnp.float32)
        got = dense(x, w, mode="ref", contract_dims=2)
        want = jnp.einsum("bshk,hkd->bsd", x, w)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_bias_matches_post_add(self):
        x = seq_input()
        w = jax.random.normal(KEY, (D, 4, 8), jnp.float32)
        b = jax.random.normal(KEY, (4, 8), jnp.float32)
        got = dense(x, w, bias=b, mode="ref")
        want = jnp.einsum("bsd,dhk->bshk", x, w) + b
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)

    def test_2d_x_leading_dims(self):
        x = jax.random.normal(KEY, (B, D), jnp.float32)
        w = jax.random.normal(KEY, (D, 4, 8), jnp.float32)
        got = dense(x, w, mode="ref")
        want = jnp.einsum("bd,dhk->bhk", x, w)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_shape_mismatch_raises(self):
        x = seq_input()
        w = jax.random.normal(KEY, (4, 8, D), jnp.float32)
        with pytest.raises(ValueError, match="contraction mismatch"):
            dense(x, w, mode="ref")

    def test_interpret_kernel_matches_ref_on_projection(self):
        x = seq_input()
        w = jax.random.normal(KEY, (D, 4, 8), jnp.float32)
        got = dense(x, w, mode="interpret")
        want = dense(x, w, mode="ref")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# attention: mha / gqa (+bias) / mla / cross — vs seed einsum oracles
# ---------------------------------------------------------------------------

def _gqa_oracle(p, c, x, pos):
    q = jnp.einsum("bsd,dhk->bshk", x, p["w_q"])
    k = jnp.einsum("bsd,dgk->bsgk", x, p["w_k"])
    v = jnp.einsum("bsd,dgk->bsgk", x, p["w_v"])
    if c.qkv_bias:
        q = q + p["b_q"].astype(q.dtype)
        k = k + p["b_k"].astype(k.dtype)
        v = v + p["b_v"].astype(v.dtype)
    q = rope(q, pos, c.rope_theta)
    k = rope(k, pos, c.rope_theta)
    out = attn._attend(q, k, v, 1.0 / math.sqrt(c.head_dim), window=c.window)
    return jnp.einsum("bshk,hkd->bsd", out, p["w_o"])


@pytest.mark.parametrize("kv_heads,bias", [(4, False), (2, False), (2, True)],
                         ids=["mha", "gqa", "gqa_bias"])
def test_gqa_parity(kv_heads, bias):
    c = attn.AttnConfig(d_model=D, num_heads=4, num_kv_heads=kv_heads,
                        head_dim=8, qkv_bias=bias, dtype=jnp.float32,
                        dense_mode="ref")
    p = init(attn.attn_specs(c))
    x = seq_input()
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    assert_fwd_and_grad(lambda p, x: attn.gqa_forward(p, c, x, pos),
                        lambda p, x: _gqa_oracle(p, c, x, pos), p, x)


def _mla_oracle(p, c, x, pos):
    nope = c.head_dim
    if c.q_lora_rank:
        cq = rmsnorm({"scale": p["q_norm"]}, x @ p["w_dq"])
        q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["w_q"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q = jnp.concatenate([q_nope, rope(q_rope, pos, c.rope_theta)], axis=-1)
    d = x @ p["w_dkv"]
    c_kv, k_rope = d[..., : c.kv_lora_rank], d[..., c.kv_lora_rank:]
    c_kv = rmsnorm({"scale": p["kv_norm"]}, c_kv)
    k_rope = rope(k_rope[..., None, :], pos, c.rope_theta)[..., 0, :]
    k_nope = jnp.einsum("btr,rhk->bthk", c_kv, p["w_uk"])
    v = jnp.einsum("btr,rhk->bthk", c_kv, p["w_uv"])
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (*k_nope.shape[:3], c.rope_head_dim))], axis=-1)
    out = attn._sdpa_chunked(q, k, v, 1.0 / math.sqrt(nope + c.rope_head_dim))
    return jnp.einsum("bshk,hkd->bsd", out, p["w_o"])


@pytest.mark.parametrize("q_lora", [None, 12], ids=["mla", "mla_qlora"])
def test_mla_parity(q_lora):
    c = attn.AttnConfig(d_model=D, num_heads=4, num_kv_heads=4, head_dim=8,
                        kv_lora_rank=16, q_lora_rank=q_lora, rope_head_dim=4,
                        dtype=jnp.float32, dense_mode="ref")
    p = init(attn.attn_specs(c))
    x = seq_input()
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    assert_fwd_and_grad(lambda p, x: attn.mla_forward(p, c, x, pos),
                        lambda p, x: _mla_oracle(p, c, x, pos), p, x)


def _cross_oracle(p, c, x, enc):
    q = jnp.einsum("bsd,dhk->bshk", x, p["w_q"])
    k = jnp.einsum("btd,dgk->btgk", enc, p["w_k"])
    v = jnp.einsum("btd,dgk->btgk", enc, p["w_v"])
    q = rmsnorm({"scale": p["q_norm"]}, q)
    k = rmsnorm({"scale": p["k_norm"]}, k)
    mask = jnp.ones((x.shape[0], x.shape[1], enc.shape[1]), bool)
    out = attn._sdpa(q, k, v, mask, 1.0 / math.sqrt(c.head_dim))
    return jnp.einsum("bshk,hkd->bsd", out, p["w_o"])


def test_cross_attn_parity():
    c = attn.AttnConfig(d_model=D, num_heads=4, num_kv_heads=4, head_dim=8,
                        dtype=jnp.float32, dense_mode="ref")
    p = init(attn.cross_attn_specs(c))
    x = seq_input()
    enc = seq_input(s=8, key=jax.random.PRNGKey(9))
    assert_fwd_and_grad(lambda p, x: attn.cross_attn_forward(p, c, x, enc),
                        lambda p, x: _cross_oracle(p, c, x, enc), p, x)


# ---------------------------------------------------------------------------
# MoE — vs the seed batched-einsum expert FFN + raw router matmul
# ---------------------------------------------------------------------------

def _moe_oracle(p, c, x):
    """Seed moe_apply (no-mesh grouped path) with raw einsums throughout."""
    B_, S_, D_ = x.shape
    T = B_ * S_
    G = moe_mod._dispatch_groups(c, T)
    Tg = T // G
    C = moe_mod.capacity(c, Tg)
    xg = x.reshape(G, Tg, D_)

    def dispatch(xt):
        k, E = c.experts_per_token, c.num_experts
        logits = xt.astype(c.router_dtype) @ p["router"].astype(c.router_dtype)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
        flat_e = top_e.reshape(-1)
        order = jnp.argsort(flat_e)
        sorted_e = flat_e[order]
        grp_start = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
        slot = jnp.arange(Tg * k) - grp_start[sorted_e]
        keep = slot < C
        token_idx = order // k
        buf = jnp.zeros((E, C, D_), xt.dtype)
        buf = buf.at[sorted_e, jnp.where(keep, slot, 0)].add(
            jnp.where(keep[:, None], xt[token_idx], 0).astype(xt.dtype))
        w = top_p.reshape(-1)[order]
        return buf, (sorted_e, slot, keep, token_idx, w)

    buf, meta = jax.vmap(dispatch)(xg)
    wg, wu, wd = p["w_gate"], p["w_up"], p["w_down"]
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, wg))
    h = h * jnp.einsum("gecd,edf->gecf", buf, wu)
    out_buf = jnp.einsum("gecf,efd->gecd", h, wd)
    out = jax.vmap(lambda ob, m: moe_mod._combine(ob, m, Tg, x.dtype))(out_buf, meta)
    out = out.reshape(B_, S_, D_)
    if c.num_shared_experts:
        xt = x.reshape(T, D_)
        sh = p["shared"]
        hs = jax.nn.silu(xt @ sh["w_gate"]) * (xt @ sh["w_up"])
        out = out + (hs @ sh["w_down"]).reshape(B_, S_, D_)
    return out


@pytest.mark.parametrize("shared", [0, 1], ids=["moe", "moe_shared"])
def test_moe_parity(shared):
    c = moe_mod.MoeConfig(d_model=D, d_ff=24, num_experts=8,
                          experts_per_token=2, capacity_factor=8.0,
                          num_shared_experts=shared, dtype=jnp.float32,
                          dispatch_groups=4, dense_kernel="ref")
    p = init(moe_mod.moe_specs(c))
    x = seq_input()
    assert_fwd_and_grad(lambda p, x: moe_mod.moe_apply(p, c, x),
                        lambda p, x: _moe_oracle(p, c, x), p, x)


# ---------------------------------------------------------------------------
# SSM — vs seed raw-matmul projections
# ---------------------------------------------------------------------------

def _ssm_oracle(p, c, u):
    """Seed ssm_forward: _ssd_chunked with raw @-projections."""
    import repro.models.ssm as S_

    B_, S_len, _ = u.shape
    H, P_, N = c.n_heads, c.head_dim, c.d_state
    xz = u @ p["w_in"]
    x, z = jnp.split(xz, 2, axis=-1)
    x = S_._conv1d_causal(x, p["conv_w"])
    x = jax.nn.silu(x)
    bc = u @ p["w_bc"]
    Bm, Cm = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus((u @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    a = jnp.exp(-dt * jnp.exp(p["A_log"]))

    Lc = min(S_.SSD_CHUNK, S_len)
    nc = S_len // Lc
    xh = x.reshape(B_, S_len, H, P_).astype(jnp.float32)
    loga = jnp.log(jnp.maximum(a, 1e-30))

    def resh(t):
        return t.reshape(B_, nc, Lc, *t.shape[2:]).swapaxes(0, 1)

    xs, Bs, Cs, dts, logas = map(resh, (
        xh, Bm.astype(jnp.float32), Cm.astype(jnp.float32), dt, loga))
    s0 = jnp.zeros((B_, H, P_, N), jnp.float32)

    def step(s_prev, inp):
        xc, bc_, cc, dtc, lac = inp
        A = jnp.cumsum(lac, axis=1)
        decay = A[:, :, None, :] - A[:, None, :, :]
        causal = jnp.tril(jnp.ones((Lc, Lc), bool))
        decay = jnp.where(causal[None, :, :, None], decay, -jnp.inf)
        gates = jnp.exp(decay) * dtc[:, None, :, :]
        scores = jnp.einsum("btn,bsn->bts", cc, bc_)
        w = gates * scores[..., None]
        y_intra = jnp.einsum("btsh,bshp->bthp", w, xc)
        y_inter = jnp.exp(A)[..., None] * jnp.einsum("btn,bhpn->bthp", cc, s_prev)
        wA = jnp.exp(A[:, -1:, :] - A) * dtc
        s_new = (s_prev * jnp.exp(A[:, -1])[..., None, None]
                 + jnp.einsum("bsh,bshp,bsn->bhpn", wA, xc, bc_))
        return s_new, y_intra + y_inter

    _, ys = jax.lax.scan(step, s0, (xs, Bs, Cs, dts, logas))
    y = ys.swapaxes(0, 1).reshape(B_, S_len, H, P_)
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(B_, S_len, H * P_).astype(u.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["w_out"]


def test_ssm_parity():
    c = ssm_mod.SsmConfig(d_model=D, d_inner=2 * D, d_state=8, n_heads=4,
                          dtype=jnp.float32, dense_mode="ref")
    p = init(ssm_mod.ssm_specs(c))
    x = seq_input()
    assert_fwd_and_grad(lambda p, x: ssm_mod.ssm_forward(p, c, x),
                        lambda p, x: _ssm_oracle(p, c, x), p, x)


# ---------------------------------------------------------------------------
# xLSTM — vs seed einsum projections
# ---------------------------------------------------------------------------

def _mlstm_oracle(p, c, x):
    hd = c.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["w_q"]).astype(jnp.float32)
    k = jnp.einsum("bsd,dhk->bshk", x, p["w_k"]).astype(jnp.float32) / (hd ** 0.5)
    v = jnp.einsum("bsd,dhk->bshk", x, p["w_v"]).astype(jnp.float32)
    i = (x @ p["w_i"]).astype(jnp.float32) + p["b_i"]
    f = (x @ p["w_f"]).astype(jnp.float32) + p["b_f"]
    logf = -jax.nn.softplus(-f)

    import repro.models.xlstm as X_
    B_ = x.shape[0]
    orig_qkv, orig_gates = X_._mlstm_qkv, X_._mlstm_gates
    X_._mlstm_qkv = lambda *_: (q, k, v)
    X_._mlstm_gates = lambda *_: (i, logf)
    try:
        hid, _ = X_._mlstm_chunk_scan(p, c, x, X_._mlstm_state0(c, B_))
    finally:
        X_._mlstm_qkv, X_._mlstm_gates = orig_qkv, orig_gates
    o = jax.nn.sigmoid(x @ p["ogate"])
    y = jnp.einsum("bthk,hkd->btd", hid.astype(x.dtype), p["w_o"])
    return y * o


def test_mlstm_parity():
    c = xlstm_mod.XlstmConfig(d_model=D, n_heads=4, dtype=jnp.float32,
                              dense_mode="ref")
    p = init(xlstm_mod.mlstm_specs(c))
    x = seq_input()
    assert_fwd_and_grad(lambda p, x: xlstm_mod.mlstm_forward(p, c, x),
                        lambda p, x: _mlstm_oracle(p, c, x), p, x)


def _slstm_oracle(p, c, x):
    B_, S_len, D_ = x.shape
    z = jnp.tanh((x @ p["w_z"]).astype(jnp.float32)).reshape(
        B_, S_len, c.n_heads, c.head_dim)
    i = (x @ p["w_i"]).astype(jnp.float32) + p["b_i"]
    f = (x @ p["w_f"]).astype(jnp.float32) + p["b_f"]
    logf = -jax.nn.softplus(-f)
    og = jax.nn.sigmoid(x @ p["w_og"])
    state0 = {
        "c": jnp.zeros((B_, c.n_heads, c.head_dim), jnp.float32),
        "n": jnp.zeros((B_, c.n_heads), jnp.float32),
        "m": jnp.full((B_, c.n_heads), -1e30, jnp.float32),
    }

    def step(st, xs):
        return xlstm_mod._slstm_step(p, c, st, xs)

    _, hs = jax.lax.scan(
        step, state0,
        (z.swapaxes(0, 1), i.swapaxes(0, 1), logf.swapaxes(0, 1),
         jnp.zeros((S_len, 1), jnp.float32)))
    h = hs.swapaxes(0, 1).reshape(B_, S_len, D_).astype(x.dtype)
    return (h * og) @ p["w_out"]


def test_slstm_parity():
    c = xlstm_mod.XlstmConfig(d_model=D, n_heads=4, dtype=jnp.float32,
                              dense_mode="ref")
    p = init(xlstm_mod.slstm_specs(c))
    x = seq_input()
    assert_fwd_and_grad(lambda p, x: xlstm_mod.slstm_forward(p, c, x),
                        lambda p, x: _slstm_oracle(p, c, x), p, x)


# ---------------------------------------------------------------------------
# dense_grouped: interpret-mode kernel parity at ragged expert-capacity
# ---------------------------------------------------------------------------

class TestDenseGrouped:
    def test_ragged_capacity_interpret_matches_oracle(self):
        """C=13 / F=40 don't divide any tile size: zero-padding + expert-ring
        schedule must still match the batched-einsum oracle."""
        E, C, D_, F = 4, 13, 24, 40
        k1, k2, k3 = jax.random.split(KEY, 3)
        x = jax.random.normal(k1, (E, C, D_), jnp.float32)
        w = jax.random.normal(k2, (E, D_, F), jnp.float32)
        b = jax.random.normal(k3, (E, F), jnp.float32)
        got = dense_grouped(x, w, bias=b, activation="silu", mode="interpret")
        want = dense_grouped_ref(x, w, bias=b, activation="silu")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_multi_tile_expert_ring(self):
        """Pinned small tiles force a multi-step grid so the ring pipelines
        across expert boundaries (the outer ring dimension)."""
        E, C, D_, F = 3, 17, 48, 256
        k1, k2 = jax.random.split(KEY)
        x = jax.random.normal(k1, (E, C, D_), jnp.float32)
        w = jax.random.normal(k2, (E, D_, F), jnp.float32)
        got = gpp_matmul_grouped(x, w, block_m=8, block_n=128, block_k=16,
                                 num_bufs=3, interpret=True)
        want = dense_grouped_ref(x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_insitu_and_naive_rings(self):
        E, C, D_, F = 2, 8, 16, 128
        k1, k2 = jax.random.split(KEY)
        x = jax.random.normal(k1, (E, C, D_), jnp.float32)
        w = jax.random.normal(k2, (E, D_, F), jnp.float32)
        want = dense_grouped_ref(x, w)
        for G in (1, 2):
            got = gpp_matmul_grouped(x, w, num_bufs=G, interpret=True)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-5, atol=1e-5, err_msg=f"G={G}")

    def test_kernel_path_gradients_match_ref(self):
        E, C, D_, F = 4, 13, 24, 40
        k1, k2 = jax.random.split(KEY)
        x = jax.random.normal(k1, (E, C, D_), jnp.float32)
        w = jax.random.normal(k2, (E, D_, F), jnp.float32)

        def loss(mode):
            return lambda xx, ww: (
                dense_grouped(xx, ww, activation="silu", mode=mode) ** 2).mean()

        gx_k, gw_k = jax.grad(loss("interpret"), argnums=(0, 1))(x, w)
        gx_r, gw_r = jax.grad(loss("ref"), argnums=(0, 1))(x, w)
        np.testing.assert_allclose(np.asarray(gx_k), np.asarray(gx_r),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(gw_k), np.asarray(gw_r),
                                   rtol=1e-5, atol=1e-5)

    def test_shape_validation(self):
        x = jnp.zeros((2, 4, 8))
        with pytest.raises(ValueError, match="grouped shape mismatch"):
            dense_grouped(x, jnp.zeros((3, 8, 16)), mode="ref")
        with pytest.raises(ValueError, match="wants"):
            dense_grouped(jnp.zeros((4, 8)), jnp.zeros((3, 8, 16)), mode="ref")


# ---------------------------------------------------------------------------
# TimingCache: measurements override the analytic model in the planner
# ---------------------------------------------------------------------------

class TestTimingCache:
    def test_measured_rates_change_tile_choice(self):
        """The analytic model (small M => DMA-bound) plans a deep ring; a
        TimingCache whose measurements say compute is the bottleneck must
        flip the plan to a shallow ring."""
        M, K, N = 8, 4096, 8192   # small-M: analytically t_dma >> t_compute
        base = plan_matmul_tiles(M, K, N)
        assert base.num_bufs >= 3  # sanity: analytic model wants a deep ring

        # contradicting measurements: transfers are ~instant, compute is slow
        tc = TimingCache()
        for _ in range(3):
            tc.record(block_bytes=1e6, compute_flops=1e9,
                      t_dma=1e-6, t_compute=1e-2)
        measured = plan_matmul_tiles(M, K, N, timing=tc)
        assert measured.num_bufs == 2  # compute-bound: naive double-buffer
        assert measured.num_bufs != base.num_bufs

    def test_median_rejects_outlier(self):
        tc = TimingCache()
        # steady-state: 1 GB/s; one preempted outlier at 1 KB/s
        for t in (1e-3, 1e-3, 1e-3, 1.0):
            tc.record(block_bytes=1e6, compute_flops=1e9,
                      t_dma=t, t_compute=1e-3)
        _, bps = tc.effective_rates()
        assert bps == pytest.approx(1e9)

    def test_default_cache_install(self):
        M, K, N = 8, 4096, 8192
        base = plan_matmul_tiles(M, K, N)
        tc = TimingCache()
        tc.record(block_bytes=1e6, compute_flops=1e9,
                  t_dma=1e-6, t_compute=1e-2)
        set_default_timing_cache(tc)
        try:
            assert plan_matmul_tiles(M, K, N).num_bufs != base.num_bufs
            # explicitly passed rates beat the ambient default cache
            from repro.core.schedule import HBM_BYTES_PER_S, PEAK_FLOPS
            explicit = plan_matmul_tiles(M, K, N, flops_per_s=PEAK_FLOPS * 2)
            no_cache = plan_matmul_tiles(M, K, N, flops_per_s=PEAK_FLOPS * 2,
                                         timing=TimingCache())
            assert explicit.num_bufs == no_cache.num_bufs
        finally:
            set_default_timing_cache(None)
        assert plan_matmul_tiles(M, K, N).num_bufs == base.num_bufs

    def test_json_roundtrip(self, tmp_path):
        import json
        tc = TimingCache()
        tc.record(block_bytes=2e6, compute_flops=3e9, t_dma=2e-4, t_compute=1e-4)
        bench = {"dense_timing_samples": {"samples": tc.to_json()}}
        path = tmp_path / "BENCH_kernels.json"
        path.write_text(json.dumps(bench))
        tc2 = TimingCache.from_bench_json(str(path))
        assert len(tc2) == 1
        assert tc2.effective_rates() == tc.effective_rates()


class TestTimingProvenance:
    """measured_on tags: compiled-run samples outrank host ones."""

    def test_compiled_samples_preferred(self):
        tc = TimingCache()
        # host loop says the link is slow (deep ring)...
        tc.record(block_bytes=1e6, compute_flops=1e9,
                  t_dma=1e-2, t_compute=1e-6, measured_on="host")
        # ...the compiled run says compute is the bottleneck (shallow ring)
        tc.record(block_bytes=1e6, compute_flops=1e9,
                  t_dma=1e-6, t_compute=1e-2, measured_on="compiled")
        fps, bps = tc.effective_rates()
        assert bps == pytest.approx(1e6 / 1e-6)     # compiled sample only
        assert fps == pytest.approx(1e9 / 1e-2)
        shallow = plan_matmul_tiles(8, 4096, 8192, timing=tc)
        assert shallow.num_bufs == 2                # compiled verdict wins

    def test_host_only_cache_unchanged(self):
        tc = TimingCache()
        tc.record(block_bytes=1e6, compute_flops=1e9,
                  t_dma=1e-3, t_compute=1e-3)       # default: host
        assert tc.samples[0].measured_on == "host"
        fps, bps = tc.effective_rates()
        assert bps == pytest.approx(1e9)

    def test_bad_provenance_rejected(self):
        with pytest.raises(ValueError):
            TimingCache().record(block_bytes=1e6, compute_flops=1e9,
                                 t_dma=1e-3, t_compute=1e-3,
                                 measured_on="gpu-ish")

    def test_json_roundtrip_preserves_and_defaults_provenance(self):
        tc = TimingCache()
        tc.record(block_bytes=1e6, compute_flops=1e9, t_dma=1e-3,
                  t_compute=1e-3, measured_on="compiled")
        tc2 = TimingCache.from_json(tc.to_json())
        assert tc2.samples[0].measured_on == "compiled"
        # pre-provenance records (no measured_on key) load as host samples
        legacy = [{"block_bytes": 1e6, "compute_flops": 1e9,
                   "t_dma": 1e-3, "t_compute": 1e-3}]
        assert TimingCache.from_json(legacy).samples[0].measured_on == "host"
