"""Serving engine: continuous batching over the (now paged) engine.

These are the seed engine's behavioural tests, kept verbatim against the
rewritten paged `ServingEngine` — passing them means the new engine is a
drop-in replacement; `test_serving_paged.py` covers the paged-specific
surface (parity, preemption, bounded compilation) and the dense seed
engine lives on in `serving.dense_engine`."""
import numpy as np
import pytest

import jax

from repro.models import registry
from repro.models import transformer as tf
from repro.serving.engine import ServeConfig, ServingEngine

pytestmark = pytest.mark.tier1


@pytest.fixture(scope="module")
def engine_setup():
    cfg = registry.get_config("qwen1.5-0.5b", smoke=True)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


class TestServingEngine:
    def test_single_request(self, engine_setup):
        cfg, params = engine_setup
        eng = ServingEngine(cfg, params, ServeConfig(slots=2, max_len=64))
        rid = eng.submit([1, 2, 3, 4], max_new_tokens=6)
        results = eng.run()
        assert len(results[rid]) == 6
        assert all(0 <= t < cfg.vocab_size for t in results[rid])

    def test_more_requests_than_slots(self, engine_setup):
        """Continuous batching: 5 requests through 2 slots all complete."""
        cfg, params = engine_setup
        eng = ServingEngine(cfg, params, ServeConfig(slots=2, max_len=64))
        rng = np.random.default_rng(0)
        rids = [eng.submit(rng.integers(0, cfg.vocab_size, size=5).tolist(),
                           max_new_tokens=4) for _ in range(5)]
        results = eng.run()
        assert sorted(results) == sorted(rids)
        assert all(len(results[r]) == 4 for r in rids)

    def test_greedy_determinism(self, engine_setup):
        """Same prompt twice (different lanes) must decode identically."""
        cfg, params = engine_setup
        eng = ServingEngine(cfg, params, ServeConfig(slots=2, max_len=64))
        prompt = [7, 8, 9, 10, 11]
        r1 = eng.submit(prompt, max_new_tokens=5)
        r2 = eng.submit(prompt, max_new_tokens=5)
        results = eng.run()
        assert results[r1] == results[r2]

    def test_dense_kernel_override_threads_through(self, engine_setup):
        """ServeConfig.dense_kernel overrides cfg routing for streamed dense
        layers at serve time, and the explicit-"ref" engine decodes the same
        tokens as the default ("auto" resolves to ref on CPU)."""
        cfg, params = engine_setup
        eng = ServingEngine(cfg, params,
                            ServeConfig(slots=1, max_len=64, dense_kernel="ref"))
        assert eng.cfg.dense_kernel == "ref"
        base = ServingEngine(cfg, params, ServeConfig(slots=1, max_len=64))
        assert base.cfg.dense_kernel == cfg.dense_kernel
        prompt = [5, 6, 7]
        r1 = eng.submit(prompt, max_new_tokens=4)
        r2 = base.submit(prompt, max_new_tokens=4)
        assert eng.run()[r1] == base.run()[r2]

    def test_matches_manual_decode(self, engine_setup):
        """Engine output == hand-rolled prefill+decode loop."""
        import jax.numpy as jnp
        cfg, params = engine_setup
        prompt = [3, 1, 4, 1, 5]
        eng = ServingEngine(cfg, params, ServeConfig(slots=1, max_len=64))
        rid = eng.submit(prompt, max_new_tokens=4)
        got = eng.run()[rid]

        toks = jnp.asarray([prompt], jnp.int32)
        logits, caches = tf.prefill(params, cfg, {"tokens": toks}, max_len=64)
        expect = [int(jnp.argmax(logits[0, -1]))]
        pos = len(prompt)
        for _ in range(3):
            logits, caches = tf.decode_step(
                params, cfg, jnp.asarray([[expect[-1]]], jnp.int32), caches, pos)
            expect.append(int(jnp.argmax(logits[0, -1])))
            pos += 1
        assert got == expect
