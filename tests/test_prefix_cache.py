"""Shared-prefix KV reuse: radix-tree index units, copy-on-write fork
semantics, refcount invariants under preempt/resume, per-layer-group
reclamation, and token-for-token parity of shared-prefix vs cold-prefill
serving on the three attention families."""
import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core.schedule import plan_serve_chunk
from repro.serving.cache import GroupedPagedCache, PagedKVCache
from repro.serving.prefix import PrefixCache

pytestmark = pytest.mark.tier1

BS = 4


def make_cache(groups=1, num_blocks=33, slots=2, mb=16, horizons=None):
    horizons = horizons if horizons is not None else (None,) * groups
    return GroupedPagedCache(slots=slots, num_blocks=num_blocks,
                             block_size=BS, max_blocks_per_seq=mb,
                             horizons=horizons)


def lane_insert(kv, pc, lane, tokens):
    """Map fresh blocks for `tokens` on `lane` and index them — the engine's
    insert-at-prefill-complete path in miniature."""
    tokens = np.asarray(tokens, np.int32)
    assert kv.ensure(lane, len(tokens) - 1)
    n = -(-len(tokens) // BS)
    return pc.insert(tokens, kv.table_snapshot(lane, n))


class TestRadixIndex:
    def test_roundtrip_and_cap(self):
        kv = make_cache()
        pc = PrefixCache(kv)
        toks = np.arange(100, 100 + 3 * BS, dtype=np.int32)   # 3 full blocks
        lane_insert(kv, pc, 0, toks)
        # identical query: cap at len-1 keeps the last token computed
        hit = pc.match(toks)
        assert hit.tokens == len(toks) - 1
        assert len(hit.blocks[0]) == 2 and hit.tail is not None
        # longer query: all 3 blocks reusable
        hit = pc.match(np.concatenate([toks, [1, 2]]).astype(np.int32))
        assert hit.tokens == 3 * BS and hit.tail is None
        assert list(hit.blocks[0]) == kv.groups[0].table_snapshot(0, 3)
        # disjoint query: miss
        assert pc.match(np.arange(50, 60, dtype=np.int32)).tokens == 0
        assert pc.hit_rate() == pytest.approx(2 / 3)

    def test_block_boundary_split(self):
        kv = make_cache()
        pc = PrefixCache(kv)
        a = list(range(100, 100 + 2 * BS))
        lane_insert(kv, pc, 0, a + [1, 2, 3, 4])   # shares 2 blocks, then b1
        lane_insert(kv, pc, 1, a + [5, 6, 7, 8])   # diverges at the boundary
        q = np.asarray(a + [5, 6, 7, 8, 9], np.int32)
        hit = pc.match(q)
        assert hit.tokens == 3 * BS
        # the common 2 blocks come from lane 0's insert (canonical copy)
        assert list(hit.blocks[0][:2]) == kv.groups[0].table_snapshot(0, 2)
        assert hit.blocks[0][2] == kv.groups[0].table_snapshot(1, 3)[2]

    def test_mid_block_divergence_forks_partial(self):
        kv = make_cache()
        pc = PrefixCache(kv)
        lane_insert(kv, pc, 0, [10, 11, 12, 13, 20, 21, 22, 23])
        # diverges INSIDE block 1: only 2 of its tokens match -> the hit
        # forks lane 0's block (copy-on-write source), sharing 6 tokens
        hit = pc.match(np.asarray([10, 11, 12, 13, 20, 21, 9, 9, 9], np.int32))
        assert hit.tokens == BS + 2
        assert hit.tail == (kv.groups[0].table_snapshot(0, 2)[1],)

    def test_tail_survives_extension_upgrade(self):
        kv = make_cache()
        pc = PrefixCache(kv)
        # first insert ends mid-block (tail); re-insert extends it full
        lane_insert(kv, pc, 0, [10, 11, 12, 13, 20, 21])
        held_before = pc.blocks_held
        lane_insert(kv, pc, 1, [10, 11, 12, 13, 20, 21, 22, 23, 30])
        # a divergent continuation still partial-matches the first 6 tokens
        hit = pc.match(np.asarray([10, 11, 12, 13, 20, 21, 7, 7], np.int32))
        assert hit.tokens == BS + 2 and hit.tail is not None
        assert pc.blocks_held >= held_before
        kv.check_invariants(pc.held_blocks())

    def test_lru_eviction_zero_lane_ref_only(self):
        kv = make_cache(num_blocks=9, mb=8)       # 8 allocatable blocks
        pc = PrefixCache(kv)
        lane_insert(kv, pc, 0, list(range(10, 10 + 2 * BS)))   # older
        lane_insert(kv, pc, 1, list(range(50, 50 + 2 * BS)))   # newer
        kv.free_lane(0)                  # lane refs drop; index keeps both
        assert kv.blocks_in_use == 4
        # lane 1 still maps its blocks -> NOT evictable; lane 0's are
        freed = pc.evict(8)
        assert freed == 2                # only the zero-lane-ref leaf went
        assert pc.match(np.asarray(list(range(10, 19)), np.int32)).tokens == 0
        assert pc.match(np.asarray(list(range(50, 59)), np.int32)).tokens == 8
        kv.free_lane(1)
        assert pc.evict(8) == 2
        assert kv.blocks_in_use == 0
        kv.check_invariants(pc.held_blocks())

    def test_lru_order(self):
        kv = make_cache()
        pc = PrefixCache(kv)
        lane_insert(kv, pc, 0, list(range(10, 10 + 2 * BS)))
        lane_insert(kv, pc, 1, list(range(50, 50 + 2 * BS)))
        kv.free_lane(0)
        kv.free_lane(1)
        pc.match(np.asarray(list(range(10, 19)), np.int32))   # touch older
        pc.evict(1)                      # LRU: the untouched (50..) leaf goes
        assert pc.match(np.asarray(list(range(10, 19)), np.int32)).tokens == 8
        assert pc.match(np.asarray(list(range(50, 59)), np.int32)).tokens == 0

    def test_max_blocks_cap(self):
        kv = make_cache()
        pc = PrefixCache(kv, max_blocks=2)
        lane_insert(kv, pc, 0, list(range(10, 10 + 4 * BS)))
        assert pc.blocks_held == 4       # lane still maps them: no eviction
        kv.free_lane(0)
        pc.enforce_cap()                 # the engine's finish-path hook
        assert pc.blocks_held <= 2
        kv.check_invariants(pc.held_blocks())

    def test_window_null_feasibility(self):
        kv = make_cache(horizons=(2 * BS,))      # window = 2 blocks
        pc = PrefixCache(kv)
        toks = np.arange(100, 100 + 4 * BS, dtype=np.int32)
        assert kv.ensure(0, len(toks) - 1)
        # blocks 0..1 expired behind the window before insert
        kv.groups[0].release_expired(0, len(toks) - 1, 2 * BS)
        pc.insert(toks, kv.table_snapshot(0, 4))
        # full-length match: nulls sit wholly behind the window -> usable
        q = np.concatenate([toks, [1, 2]]).astype(np.int32)
        assert pc.match(q).tokens == 4 * BS
        # a SHORT query would need the nulled early blocks inside its
        # window -> no usable prefix
        assert pc.match(toks[: 2 * BS + 2]).tokens == 0

    def test_global_group_rejects_nulls(self):
        kv = make_cache(groups=2, horizons=(None, 2 * BS))
        pc = PrefixCache(kv)
        toks = np.arange(100, 100 + 4 * BS, dtype=np.int32)
        assert kv.ensure(0, len(toks) - 1)
        kv.groups[1].release_expired(0, len(toks) - 1, 2 * BS)  # window group
        pc.insert(toks, kv.table_snapshot(0, 4))
        q = np.concatenate([toks, [1, 2]]).astype(np.int32)
        assert pc.match(q).tokens == 4 * BS   # global group fully backed
        # now a hole in a GLOBAL group: match must stop before it (every
        # later query still reads the whole history there)
        kv2 = make_cache(groups=2, horizons=(None, None))
        pc2 = PrefixCache(kv2)
        assert kv2.ensure(0, len(toks) - 1)
        snap = kv2.table_snapshot(0, 4)
        crippled = ([snap[0][0], 0, snap[0][2], snap[0][3]], list(snap[1]))
        # drop the lane ref for the entry the snapshot punched out
        kv2.groups[0]._release([snap[0][1]])
        kv2.groups[0].tables[0, 1] = 0
        pc2.insert(toks, crippled)
        assert pc2.match(q).tokens == BS      # stops at the global hole

    def test_remap_after_defragment(self):
        kv = make_cache(slots=3)
        pc = PrefixCache(kv)
        lane_insert(kv, pc, 0, list(range(10, 10 + 2 * BS)))
        lane_insert(kv, pc, 1, list(range(50, 50 + 3 * BS)))
        kv.free_lane(0)                       # hole in the pool
        perms = kv.defragment()
        pc.remap(tuple(PagedKVCache.old_to_new(p) for p in perms))
        hit = pc.match(np.asarray(list(range(50, 66)), np.int32))
        assert hit.tokens == 3 * BS
        assert list(hit.blocks[0]) == kv.groups[0].table_snapshot(1, 3)
        kv.check_invariants(pc.held_blocks())

    @pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.lists(st.integers(0, 3), min_size=1, max_size=20),
                    min_size=1, max_size=6),
           st.lists(st.integers(0, 3), min_size=1, max_size=20))
    def test_match_blocks_spell_the_query(self, seqs, query):
        """Whatever the insert history, a hit's blocks must cover exactly
        the query's leading tokens, C <= len-1, and refcount invariants
        hold."""
        kv = make_cache(num_blocks=257, slots=1, mb=8)
        pc = PrefixCache(kv)
        spelled = {}                       # physical block -> its tokens
        for seq in seqs:
            toks = np.asarray(seq[: 8 * BS], np.int32)
            lane_insert(kv, pc, 0, toks)
            for j, b in enumerate(kv.groups[0].table_snapshot(
                    0, -(-len(toks) // BS))):
                # overwrite: a freed-and-reused id spells its NEW tokens;
                # index-adopted blocks are never reused (no eviction here)
                spelled[b] = toks[j * BS : (j + 1) * BS]
            kv.free_lane(0)
        q = np.asarray(query, np.int32)
        hit = pc.match(q)
        assert 0 <= hit.tokens <= max(0, len(q) - 1)
        nfull = hit.tokens // BS
        assert len(hit.blocks[0]) == nfull
        for j, b in enumerate(hit.blocks[0]):
            np.testing.assert_array_equal(spelled[b],
                                          q[j * BS : (j + 1) * BS])
        if hit.tail is not None:
            k = hit.tokens - nfull * BS
            np.testing.assert_array_equal(
                spelled[hit.tail[0]][:k], q[nfull * BS : hit.tokens])
        kv.check_invariants(pc.held_blocks())


class TestForkOomFallback:
    def _probe(self, horizons):
        from repro.serving.scheduler import ChunkedPrefillScheduler, Request
        # 4 allocatable blocks: the insert pins 3, the drain takes the last
        kv = make_cache(groups=len(horizons), num_blocks=5, mb=8,
                        horizons=horizons)
        pc = PrefixCache(kv)
        toks = list(range(100, 100 + 2 * BS + 2))   # 2 full blocks + 2 tail
        lane_insert(kv, pc, 0, toks)
        kv.free_lane(0)
        sched = ChunkedPrefillScheduler(kv, slots=2, chunk=BS, prefix=pc)
        req = Request(rid=0, prompt=np.asarray(toks + [1, 2], np.int32),
                      max_new=2)
        req.lane = 0
        req.context = req.prompt
        # drain the pool so the COW fork cannot allocate its copy
        assert kv.groups[0].ensure(1, BS - 1)
        assert kv.num_free == 0
        C = sched._probe_prefix(req)
        return C, kv, pc

    def test_global_model_keeps_block_aligned_floor(self):
        C, kv, pc = self._probe((None,))
        assert C == 2 * BS               # tail dropped, full blocks kept
        kv.check_invariants(pc.held_blocks())

    def test_window_model_drops_the_whole_share(self):
        """Regression: `match` validated window-null feasibility at the
        ORIGINAL C only — a fork-OOM truncation on a windowed model must
        not keep a share whose feasibility was never checked."""
        C, kv, pc = self._probe((BS * 2,))
        assert C == 0
        assert kv.groups[0].blocks_for(0) == []   # nothing left mapped
        kv.check_invariants(pc.held_blocks())


class TestCopyOnWrite:
    def test_fork_block_semantics(self):
        kv = make_cache()
        g = kv.groups[0]
        assert g.ensure(0, 2 * BS - 1)             # lane 0 owns 2 blocks
        src = g.table_snapshot(0, 2)
        kv.share_blocks(1, (list(src),))           # lane 1 shares them
        assert g.ref_count[src[0]] == 2
        # shared entry: fork remaps lane 1's entry to a fresh block
        new = g.fork_block(1, 1)
        assert new not in (None, src[1])
        assert g.tables[1, 1] == new
        assert g.ref_count[src[1]] == 1 and g.ref_count[new] == 1
        # now exclusive: fork returns the same id (no copy)
        assert g.fork_block(1, 1) == new
        kv.check_invariants()

    def test_fork_tail_queues_copies_and_oom_rolls_back(self):
        kv = make_cache(num_blocks=4, mb=8)        # 3 allocatable
        g = kv.groups[0]
        assert g.ensure(0, 2 * BS - 1)             # blocks 1,2
        kv.share_blocks(1, ([int(g.tables[0, 0])],))
        assert kv.fork_tail(1, 0)                  # copies 1 -> 3
        assert kv.pending_copies == [(0, int(g.tables[0, 0]),
                                      int(g.tables[1, 0]))]
        # pool now dry: a second shared fork must fail and roll back clean
        kv.share_blocks(0, ([int(g.tables[1, 0])],))   # re-share the fork
        assert not kv.fork_tail(0, 2)
        kv.drop_last_shared(0)
        kv.check_invariants()

    def test_shared_blocks_are_write_protected(self):
        kv = make_cache()
        g = kv.groups[0]
        assert g.ensure(0, BS - 1)
        kv.share_blocks(1, (g.table_snapshot(0, 1),))
        with pytest.raises(AssertionError):
            kv.assert_writable(1, 0, 1)
        with pytest.raises(AssertionError):
            kv.assert_writable(0, 0, 1)            # owner lost exclusivity too
        assert kv.fork_tail(1, 0)
        kv.assert_writable(1, 0, 1)                # fork restored it


class TestDefragmentShared:
    def test_defragment_remaps_every_table_referencing_a_shared_block(self):
        """Regression: two lanes share blocks; defragment moves one; BOTH
        tables (and the index) must follow the move."""
        kv = make_cache(slots=3, num_blocks=17, mb=8)
        pc = PrefixCache(kv)
        lane_insert(kv, pc, 2, list(range(80, 80 + 2 * BS)))  # filler
        lane_insert(kv, pc, 0, list(range(10, 10 + 2 * BS)))
        hit = pc.match(np.asarray(list(range(10, 10 + 2 * BS + 3)), np.int32))
        kv.share_blocks(1, tuple(list(b) for b in hit.blocks))
        kv.free_lane(2)                            # hole before shared blocks
        pool = np.arange(17)
        before = {l: [pool[b] for b in kv.groups[0].blocks_for(l)]
                  for l in (0, 1)}
        perms = kv.defragment()
        new_pool = pool[perms[0]]
        after = {l: [new_pool[b] for b in kv.groups[0].blocks_for(l)]
                 for l in (0, 1)}
        assert before == after
        assert (kv.groups[0].tables[0, :2] == kv.groups[0].tables[1, :2]).all()
        pc.remap(tuple(PagedKVCache.old_to_new(p) for p in perms))
        kv.check_invariants(pc.held_blocks())


class TestPlanServeChunk:
    def test_cached_tokens_extend_the_chunk(self):
        base = plan_serve_chunk(token_budget=36, decode_lanes=4, block_size=16)
        warm = plan_serve_chunk(token_budget=36, decode_lanes=4, block_size=16,
                                cached_tokens=16)
        assert base == 32 and warm == 48
        with pytest.raises(ValueError):
            plan_serve_chunk(token_budget=36, decode_lanes=4, block_size=16,
                             cached_tokens=-1)


# ---------------------------------------------------------------------------
# engine-level: parity, concurrency, preemption, per-group reclamation
# ---------------------------------------------------------------------------

import jax  # noqa: E402

from repro.models import registry  # noqa: E402
from repro.models import transformer as tf  # noqa: E402
from repro.serving import ServeConfig, ServingEngine  # noqa: E402

PARITY_ARCHS = ("qwen1.5-0.5b", "gemma3-12b", "deepseek-v2-lite-16b")


@pytest.fixture(scope="module")
def setups():
    out = {}
    for arch in PARITY_ARCHS:
        cfg = registry.get_config(arch, smoke=True)
        out[arch] = (cfg, tf.init_params(cfg, jax.random.PRNGKey(0)))
    return out


def _mk(cfg, params, prefix, **kw):
    base = dict(slots=2, max_len=64, block_size=8, prefill_chunk=8,
                prefix_cache=prefix)
    base.update(kw)
    return ServingEngine(cfg, params, ServeConfig(**base))


SHARED = list(range(100, 121))      # 21 tokens: 2 full blocks + 5-token tail


class TestSharedPrefixParity:
    @pytest.mark.parametrize("arch", PARITY_ARCHS)
    def test_token_for_token_vs_cold(self, setups, arch):
        """Greedy streams with the prefix cache ON (warm radix tree, COW
        tail forks, shared blocks) match prefix_cache=off exactly — on GQA
        (qwen), sliding-window local:global (gemma3), and MLA (deepseek)."""
        cfg, params = setups[arch]
        rounds = [SHARED + [7, 8, 9], SHARED + [11, 12], SHARED + [13]]

        def run(prefix):
            eng = _mk(cfg, params, prefix)
            outs = []
            for p in rounds:                 # sequential: each round can hit
                rid = eng.submit(p, max_new_tokens=4)
                eng.run()
                outs.append(eng._results[rid])
            return outs, eng

        cold, _ = run(False)
        warm, eng = run(True)
        assert warm == cold
        assert eng.prefix.hit_tokens > 0
        # gemma3's window group suppresses early hits (expired coverage
        # must be re-published by a later insert's null-upgrade first)
        assert eng.prefix.hits >= (1 if arch == "gemma3-12b" else 2)
        eng.kv.check_invariants(eng.prefix.held_blocks())

    def test_second_lane_skips_matched_prefill_entirely(self, setups):
        """Acceptance: two lanes share a >= 2-block prefix; the second
        lane's prefill runs zero chunks (hence zero KV writes) for the
        fully-matched blocks, and its stream is unchanged."""
        cfg, params = setups["qwen1.5-0.5b"]
        p1, p2 = SHARED + [7, 8, 9], SHARED + [11, 12, 13, 14]

        cold = _mk(cfg, params, False)
        c1, c2 = cold.submit(p1, 5), cold.submit(p2, 5)
        cold.run()

        eng = _mk(cfg, params, True)
        r1 = eng.submit(p1, max_new_tokens=5)
        while eng.scheduler.phase.get(0) != "decode":   # r1 prefill completes
            eng.step()
        steps_before = len(eng.metrics)
        r2 = eng.submit(p2, max_new_tokens=5)
        eng.run()
        assert [eng._results[r1], eng._results[r2]] == \
            [cold._results[c1], cold._results[c2]]
        # r2's context is 25 tokens; 21 came from the cache (2 full blocks +
        # a 5-token COW fork), so its prefill is ONE chunk, not four
        hit = sum(m["prefix_hit_tokens"] for m in eng.metrics[steps_before:])
        assert hit == 21
        r2_chunks = sum(1 for m in eng.metrics[steps_before:]
                        if m["prefill_tokens"])
        assert r2_chunks == 1
        assert max(m["blocks_shared"] for m in eng.metrics) >= 2
        eng.kv.check_invariants(eng.prefix.held_blocks())

    def test_preempt_resume_reprobes_and_matches_cold(self, setups):
        """Block pressure with the prefix cache on: evictions run before
        preemption, a preempted victim re-probes on resume (often hitting
        its own previously-published prefix), and outputs still match the
        unconstrained engine token-for-token."""
        cfg, params = setups["qwen1.5-0.5b"]
        prompts = [SHARED + [7], SHARED + [9, 9]]
        max_new = (12, 4)

        def run(**kw):
            eng = _mk(cfg, params, True, **kw)
            rids = [eng.submit(p, max_new_tokens=n)
                    for p, n in zip(prompts, max_new)]
            eng.run()
            return [eng._results[r] for r in rids], eng

        big, _ = run()
        tight, eng = run(num_blocks=8)       # 7 blocks shared by both lanes
        assert tight == big
        assert any(m["preempted"] for m in eng.metrics)
        eng.kv.check_invariants(eng.prefix.held_blocks())

    def test_defragment_with_shared_blocks_is_transparent(self, setups):
        """Regression (satellite): share a prefix across two live lanes,
        defragment mid-stream, keep decoding both — streams match the
        defrag-free run."""
        cfg, params = setups["qwen1.5-0.5b"]
        p1, p2 = SHARED + [7, 8, 9], SHARED + [11, 12, 13, 14]

        def run(defrag):
            eng = _mk(cfg, params, True)
            r1 = eng.submit(p1, max_new_tokens=8)
            while eng.scheduler.phase.get(0) != "decode":
                eng.step()
            r2 = eng.submit(p2, max_new_tokens=8)
            steps = 0
            while eng.pending and steps < 500:
                eng.step()
                steps += 1
                if defrag and steps % 3 == 0:
                    eng.defragment()
            if defrag:
                eng.kv.check_invariants(eng.prefix.held_blocks())
            return [eng._results[r1], eng._results[r2]]

        assert run(defrag=True) == run(defrag=False)

    def test_temperature_streams_reproducible_with_sharing(self, setups):
        """Sampling keys fold (seed, rid, token_idx) — prefix hits change
        which chunks run, not which tokens come out."""
        cfg, params = setups["qwen1.5-0.5b"]

        def run(prefix):
            eng = _mk(cfg, params, prefix, temperature=0.8, seed=7)
            outs = []
            for p in (SHARED + [7], SHARED + [7]):
                rid = eng.submit(p, max_new_tokens=5)
                eng.run()
                outs.append(eng._results[rid])
            return outs

        assert run(True) == run(False)


class TestPerLayerGroupTables:
    def test_gemma3_groups_split_window_and_global(self, setups):
        cfg, _ = setups["gemma3-12b"]
        assert tf.layer_group_keys(cfg) == ("window", "global")
        assert tf.group_horizons(cfg) == (cfg.window_size, None)
        qcfg, _ = setups["qwen1.5-0.5b"]
        assert tf.layer_group_keys(qcfg) == ("global",)

    def test_windowed_group_reclaims_while_global_pins(self, setups):
        """The lifted gemma3 limitation: window-layer blocks plateau during
        a long decode while global-layer blocks keep growing — and outputs
        still match the dense-engine oracle."""
        cfg, params = setups["gemma3-12b"]        # window 16, mixed stack
        eng = ServingEngine(cfg, params, ServeConfig(
            slots=1, max_len=96, block_size=8, prefill_chunk=8))
        assert eng.window_horizon is None          # whole-model condition
        assert eng.group_horizons == (16, None)
        rid = eng.submit(list(range(1, 9)), max_new_tokens=60)
        win = eng.kv.groups[tf.layer_group_keys(cfg).index("window")]
        glob = eng.kv.groups[tf.layer_group_keys(cfg).index("global")]
        peak_win = peak_glob = 0
        while eng.pending:
            eng.step()
            peak_win = max(peak_win, win.blocks_in_use)
            peak_glob = max(peak_glob, glob.blocks_in_use)
        out = eng._results[rid]
        assert len(out) == 60
        # 68-token context: global pins ceil(68/8) blocks; window plateaus
        # at <= 2 visible + 1 write block the whole way
        assert peak_glob >= 8
        assert peak_win <= 3

        from repro.serving import DenseServingEngine
        dense = DenseServingEngine(cfg, params, ServeConfig(slots=1,
                                                            max_len=96))
        drid = dense.submit(list(range(1, 9)), max_new_tokens=60)
        assert dense.run()[drid] == out

    def test_prefix_sharing_on_mixed_window_model(self, setups):
        """Prefix sharing operates per group on gemma3: the window group's
        expired entries ride along as nulls and matches stay correct."""
        cfg, params = setups["gemma3-12b"]

        def run(prefix):
            eng = _mk(cfg, params, prefix, max_len=96)
            outs = []
            for p in (SHARED + [7, 8], SHARED + [9]):
                rid = eng.submit(p, max_new_tokens=20)
                eng.run()
                outs.append(eng._results[rid])
            return outs, eng

        cold, _ = run(False)
        warm, eng = run(True)
        assert warm == cold
        assert eng.prefix.hits >= 1
        eng.kv.check_invariants(eng.prefix.held_blocks())
