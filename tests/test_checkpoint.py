"""Checkpoint manager: atomicity, corruption detection, elastic resume."""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import COMMITTED, CheckpointManager


def tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 4)), "b": jnp.ones((4,))},
        "opt": {"mu": jnp.zeros((8, 4)), "count": jnp.array(3, jnp.int32)},
    }


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        t = tree()
        mgr.save(10, t)
        restored, step = mgr.restore(t)
        assert step == 10
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_uncommitted_ignored(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, tree())
        mgr.save(2, tree(seed=2))
        # simulate crash mid-write of step 3: dir without commit marker
        os.makedirs(tmp_path / "step_3")
        assert mgr.latest_step() == 2

    def test_corruption_detected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(5, tree())
        shard = tmp_path / "step_5" / "shard_0.npz"
        data = shard.read_bytes()
        shard.write_bytes(data[:-8] + b"deadbeef")
        with pytest.raises(IOError, match="corrupt"):
            mgr.restore(tree())

    def test_gc_keeps_last_k(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, tree())
        assert mgr.all_steps() == [3, 4]

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(7, tree(), blocking=False)
        mgr.wait()
        assert mgr.latest_step() == 7

    def test_shape_mismatch_rejected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, tree())
        bad = tree()
        bad["params"]["w"] = jnp.zeros((4, 4))
        with pytest.raises(ValueError, match="shape"):
            mgr.restore(bad)

    def test_elastic_resume_across_meshes(self, tmp_path):
        """Save under one sharding, restore onto a different mesh — the
        elastic-rescale story (device count changed between jobs).  Runs in a
        subprocess with 4 forced host devices; `make_mesh_compat` keeps it
        running on both the explicit-mesh API and jax 0.4.x."""
        import os
        import subprocess
        import sys
        import textwrap
        repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        env["PYTHONPATH"] = os.path.join(repo, "src")
        code = textwrap.dedent(f"""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.checkpoint.manager import CheckpointManager
            from repro.launch.mesh import make_mesh_compat
            mesh_a = make_mesh_compat((4, 1), ("data", "model"))
            mesh_b = make_mesh_compat((2, 2), ("data", "model"))
            t = {{"w": jax.device_put(
                jnp.arange(32, dtype=jnp.float32).reshape(8, 4),
                NamedSharding(mesh_a, P("data", None)))}}
            mgr = CheckpointManager({str(tmp_path)!r})
            mgr.save(1, t)
            shardings = {{"w": NamedSharding(mesh_b, P("data", "model"))}}
            restored, _ = mgr.restore(t, shardings=shardings)
            np.testing.assert_array_equal(
                np.asarray(restored["w"]),
                np.arange(32, dtype=np.float32).reshape(8, 4))
            assert restored["w"].sharding.mesh.shape["model"] == 2
            print("OK")
        """)
        proc = subprocess.run([sys.executable, "-c", code],
                              capture_output=True, text=True, timeout=300,
                              env=env, cwd=repo)
        assert proc.returncode == 0, proc.stderr[-2000:]
        assert "OK" in proc.stdout
