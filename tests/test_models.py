"""Model-component correctness: decode == forward (recurrence equivalence),
MoE dispatch vs dense oracle, windowed attention masks, MLA cache math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import init_from_specs

pytestmark = pytest.mark.tier1

KEY = jax.random.PRNGKey(42)
B, S, D = 2, 16, 32


def init(specs, key=KEY):
    return init_from_specs(specs, key)


def seq_input(d=D, s=S, key=KEY):
    return jax.random.normal(key, (B, s, d), jnp.float32) * 0.5


class TestGQA:
    CFG = attn.AttnConfig(d_model=D, num_heads=4, num_kv_heads=2, head_dim=8,
                          dtype=jnp.float32)

    def test_prefill_decode_matches_forward(self):
        """Teacher-forced decode must reproduce the parallel forward."""
        p = init(attn.attn_specs(self.CFG))
        x = seq_input()
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        full = attn.gqa_forward(p, self.CFG, x, pos)
        half = S // 2
        out_pre, cache = attn.gqa_prefill(p, self.CFG, x[:, :half],
                                          pos[:, :half], max_len=S)
        np.testing.assert_allclose(np.asarray(out_pre), np.asarray(full[:, :half]),
                                   rtol=2e-4, atol=2e-4)
        outs = []
        for t in range(half, S):
            o, cache = attn.gqa_decode(p, self.CFG, x[:, t:t+1], cache, t)
            outs.append(o)
        dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, half:]),
                                   rtol=2e-4, atol=2e-4)

    def test_causality(self):
        """Future tokens must not affect past outputs."""
        p = init(attn.attn_specs(self.CFG))
        x = seq_input()
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        y1 = attn.gqa_forward(p, self.CFG, x, pos)
        x2 = x.at[:, -1].set(x[:, -1] + 100.0)
        y2 = attn.gqa_forward(p, self.CFG, x2, pos)
        np.testing.assert_allclose(np.asarray(y1[:, :-1]), np.asarray(y2[:, :-1]),
                                   rtol=1e-5, atol=1e-5)

    def test_window_limits_receptive_field(self):
        cfg = attn.AttnConfig(d_model=D, num_heads=4, num_kv_heads=2, head_dim=8,
                              window=4, dtype=jnp.float32)
        p = init(attn.attn_specs(cfg))
        x = seq_input()
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        y1 = attn.gqa_forward(p, cfg, x, pos)
        # perturb token 0: outputs at t >= window must be unchanged
        x2 = x.at[:, 0].set(x[:, 0] + 100.0)
        y2 = attn.gqa_forward(p, cfg, x2, pos)
        np.testing.assert_allclose(np.asarray(y1[:, 4:]), np.asarray(y2[:, 4:]),
                                   rtol=1e-5, atol=1e-5)
        assert not np.allclose(np.asarray(y1[:, 0]), np.asarray(y2[:, 0]))

    def test_window_ring_decode_matches_forward(self):
        cfg = attn.AttnConfig(d_model=D, num_heads=4, num_kv_heads=2, head_dim=8,
                              window=4, dtype=jnp.float32)
        p = init(attn.attn_specs(cfg))
        x = seq_input()
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        full = attn.gqa_forward(p, cfg, x, pos)
        half = S // 2
        out_pre, cache = attn.gqa_prefill(p, cfg, x[:, :half], pos[:, :half],
                                          max_len=S)
        outs = []
        for t in range(half, S):
            o, cache = attn.gqa_decode(p, cfg, x[:, t:t+1], cache, t)
            outs.append(o)
        dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, half:]),
                                   rtol=2e-4, atol=2e-4)

    def test_qkv_bias_changes_output(self):
        cfg = attn.AttnConfig(d_model=D, num_heads=4, num_kv_heads=2, head_dim=8,
                              qkv_bias=True, dtype=jnp.float32)
        p = init(attn.attn_specs(cfg))
        assert "b_q" in p
        x = seq_input()
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        y0 = attn.gqa_forward(p, cfg, x, pos)
        p2 = dict(p, b_q=p["b_q"] + 1.0)
        y1 = attn.gqa_forward(p2, cfg, x, pos)
        assert not np.allclose(np.asarray(y0), np.asarray(y1))


class TestMLA:
    CFG = attn.AttnConfig(d_model=D, num_heads=4, num_kv_heads=4, head_dim=8,
                          kv_lora_rank=16, rope_head_dim=4, dtype=jnp.float32)

    def test_prefill_decode_matches_forward(self):
        p = init(attn.attn_specs(self.CFG))
        x = seq_input()
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        full = attn.mla_forward(p, self.CFG, x, pos)
        half = S // 2
        _, cache = attn.mla_prefill(p, self.CFG, x[:, :half], pos[:, :half],
                                    max_len=S)
        outs = []
        for t in range(half, S):
            o, cache = attn.mla_decode(p, self.CFG, x[:, t:t+1], cache, t)
            outs.append(o)
        dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, half:]),
                                   rtol=2e-4, atol=2e-4)

    def test_cache_is_compressed(self):
        """The MLA cache must be (kv_lora + rope) wide, not heads*hd*2."""
        sp = attn.cache_specs(self.CFG, batch=B, max_len=S)
        cache_floats = sum(np.prod(s.shape) for s in jax.tree.leaves(sp))
        gqa_floats = B * S * self.CFG.num_kv_heads * self.CFG.head_dim * 2
        assert cache_floats < gqa_floats


class TestMoE:
    CFG = moe_mod.MoeConfig(d_model=D, d_ff=24, num_experts=8,
                            experts_per_token=2, capacity_factor=8.0,
                            dtype=jnp.float32)

    def test_matches_dense_oracle_at_high_capacity(self):
        """With capacity >= tokens, sort-dispatch == explicit per-token loop."""
        p = init(moe_mod.moe_specs(self.CFG))
        x = seq_input()
        y = moe_mod.moe_apply(p, self.CFG, x)

        # oracle: per-token dense computation
        xt = np.asarray(x.reshape(-1, D), np.float64)
        logits = xt @ np.asarray(p["router"], np.float64)
        probs = np.exp(logits - logits.max(-1, keepdims=True))
        probs /= probs.sum(-1, keepdims=True)
        top_e = np.argsort(-probs, axis=-1)[:, :2]
        out = np.zeros_like(xt)
        for t in range(xt.shape[0]):
            ws = probs[t, top_e[t]]
            ws = ws / ws.sum()
            for e, w in zip(top_e[t], ws):
                wg = np.asarray(p["w_gate"][e], np.float64)
                wu = np.asarray(p["w_up"][e], np.float64)
                wd = np.asarray(p["w_down"][e], np.float64)
                h = xt[t] @ wg
                h = h / (1 + np.exp(-h)) * (xt[t] @ wu)
                out[t] += w * (h @ wd)
        np.testing.assert_allclose(np.asarray(y.reshape(-1, D)), out,
                                   rtol=1e-3, atol=1e-3)

    def test_capacity_drops_tokens_not_nan(self):
        cfg = dataclass_replace(self.CFG, capacity_factor=0.25)
        p = init(moe_mod.moe_specs(cfg))
        x = seq_input()
        y = moe_mod.moe_apply(p, cfg, x)
        assert np.isfinite(np.asarray(y)).all()

    def test_shared_expert_added(self):
        cfg = dataclass_replace(self.CFG, num_shared_experts=1)
        p = init(moe_mod.moe_specs(cfg))
        x = seq_input()
        y = moe_mod.moe_apply(p, cfg, x)
        p0 = dict(p, shared=jax.tree.map(jnp.zeros_like, p["shared"]))
        y0 = moe_mod.moe_apply(p0, cfg, x)
        assert not np.allclose(np.asarray(y), np.asarray(y0))

    def test_grad_flows_to_router(self):
        p = init(moe_mod.moe_specs(self.CFG))
        x = seq_input()
        g = jax.grad(lambda pp: (moe_mod.moe_apply(pp, self.CFG, x) ** 2).mean())(p)
        assert float(jnp.abs(g["router"]).sum()) > 0


def dataclass_replace(cfg, **kw):
    import dataclasses
    return dataclasses.replace(cfg, **kw)


class TestSSM:
    CFG = ssm_mod.SsmConfig(d_model=D, d_inner=2 * D, d_state=8, n_heads=4,
                            dtype=jnp.float32)

    def test_decode_matches_forward(self):
        p = init(ssm_mod.ssm_specs(self.CFG))
        x = seq_input()
        full = ssm_mod.ssm_forward(p, self.CFG, x)
        half = S // 2
        y_pre, state = ssm_mod.ssm_prefill(p, self.CFG, x[:, :half])
        np.testing.assert_allclose(np.asarray(y_pre), np.asarray(full[:, :half]),
                                   rtol=1e-4, atol=1e-4)
        outs = []
        for t in range(half, S):
            o, state = ssm_mod.ssm_decode(p, self.CFG, x[:, t:t+1], state)
            outs.append(o)
        dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, half:]),
                                   rtol=1e-3, atol=1e-3)

    def test_state_is_constant_size(self):
        sp = ssm_mod.ssm_state_specs(self.CFG, batch=B)
        n = sum(np.prod(s.shape) for s in jax.tree.leaves(sp))
        assert n < 10_000  # O(1) in sequence length: the long_500k enabler


class TestXLSTM:
    CFG = xlstm_mod.XlstmConfig(d_model=D, n_heads=4, dtype=jnp.float32)

    def test_mlstm_decode_matches_forward(self):
        p = init(xlstm_mod.mlstm_specs(self.CFG))
        x = seq_input()
        full = xlstm_mod.mlstm_forward(p, self.CFG, x)
        half = S // 2
        y_pre, state = xlstm_mod.mlstm_prefill(p, self.CFG, x[:, :half])
        np.testing.assert_allclose(np.asarray(y_pre), np.asarray(full[:, :half]),
                                   rtol=1e-4, atol=1e-4)
        outs = []
        for t in range(half, S):
            o, state = xlstm_mod.mlstm_decode(p, self.CFG, x[:, t:t+1], state)
            outs.append(o)
        dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, half:]),
                                   rtol=1e-3, atol=1e-3)

    def test_mlstm_chunk_invariance(self):
        """Chunkwise scan must be exact: same output for any chunk size."""
        p = init(xlstm_mod.mlstm_specs(self.CFG))
        x = seq_input(s=32)
        orig = xlstm_mod.MLSTM_CHUNK
        try:
            xlstm_mod.MLSTM_CHUNK = 8
            y8 = xlstm_mod.mlstm_forward(p, self.CFG, x)
            xlstm_mod.MLSTM_CHUNK = 32
            y32 = xlstm_mod.mlstm_forward(p, self.CFG, x)
        finally:
            xlstm_mod.MLSTM_CHUNK = orig
        np.testing.assert_allclose(np.asarray(y8), np.asarray(y32),
                                   rtol=1e-4, atol=1e-4)

    def test_slstm_decode_matches_forward(self):
        p = init(xlstm_mod.slstm_specs(self.CFG))
        x = seq_input()
        full = xlstm_mod.slstm_forward(p, self.CFG, x)
        half = S // 2
        y_pre, state = xlstm_mod.slstm_prefill(p, self.CFG, x[:, :half])
        np.testing.assert_allclose(np.asarray(y_pre), np.asarray(full[:, :half]),
                                   rtol=1e-4, atol=1e-4)
        outs = []
        for t in range(half, S):
            o, state = xlstm_mod.slstm_decode(p, self.CFG, x[:, t:t+1], state)
            outs.append(o)
        dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, half:]),
                                   rtol=1e-3, atol=1e-3)
