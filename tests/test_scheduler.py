"""Chunked-prefill scheduler: flat token budget, preemption, no starvation.

Pure host-side tests (no model, no jax): the scheduler runs against the
paged cache's tables/allocator only.
"""
import numpy as np
import pytest

from repro.core.schedule import plan_serve_chunk, tokens_per_step_cov
from repro.serving.cache import PagedKVCache
from repro.serving.scheduler import ChunkedPrefillScheduler, Request

pytestmark = pytest.mark.tier1


def make_sched(*, slots=2, chunk=8, bs=4, num_blocks=None, mb=16):
    num_blocks = num_blocks or slots * mb + 1
    kv = PagedKVCache(slots=slots, num_blocks=num_blocks, block_size=bs,
                      max_blocks_per_seq=mb)
    return ChunkedPrefillScheduler(kv, slots=slots, chunk=chunk), kv


def req(rid, plen, max_new=4):
    return Request(rid=rid, prompt=np.arange(plen, dtype=np.int32),
                   max_new=max_new)


def drive(sched, *, max_steps=500):
    """Run the scheduler loop as the engine would, recording per-step token
    counts and completion order.  Decode/finish bookkeeping is simulated."""
    tokens, finished = [], []
    for _ in range(max_steps):
        plan = sched.schedule()
        if plan is None:
            break
        tokens.append(plan.scheduled_tokens)
        if plan.prefill and plan.prefill.final:
            r = sched.request_at(plan.prefill.lane)
            r.produced.append(1)
            sched.to_decode(plan.prefill.lane)
            if r.remaining <= 0:
                finished.append(sched.finish(plan.prefill.lane).rid)
        for lane in plan.decode_lanes:
            r = sched.request_at(lane)
            r.decode_pos += 1
            r.produced.append(1)
            if r.remaining <= 0:
                finished.append(sched.finish(lane).rid)
    return tokens, finished


class TestChunking:
    def test_plan_serve_chunk_block_multiple(self):
        assert plan_serve_chunk(token_budget=36, decode_lanes=4,
                                block_size=16) == 32
        assert plan_serve_chunk(token_budget=20, decode_lanes=4,
                                block_size=16) == 16
        # budget smaller than one block still yields one block
        assert plan_serve_chunk(token_budget=4, decode_lanes=4,
                                block_size=16) == 16

    def test_chunk_must_be_block_multiple(self):
        kv = PagedKVCache(slots=1, num_blocks=5, block_size=4,
                          max_blocks_per_seq=4)
        with pytest.raises(ValueError):
            ChunkedPrefillScheduler(kv, slots=1, chunk=6)

    def test_flat_token_budget(self):
        """Per-step tokens never exceed chunk + slots, and while prefill
        backlog exists every step carries exactly one full chunk."""
        sched, _ = make_sched(slots=2, chunk=8, bs=4, mb=16)
        for i in range(6):
            sched.submit(req(i, plen=19, max_new=3))
        tokens, finished = drive(sched)
        assert len(finished) == 6
        assert max(tokens) <= 8 + 2
        prefill_steps = sum(1 for t in tokens if t >= 8)
        # 6 requests x 24-token padded context / 8-token chunks
        assert prefill_steps == 6 * 3

    def test_saturating_queue_is_flatter_than_bursts(self):
        sched, _ = make_sched(slots=4, chunk=8, bs=4, mb=16)
        for i in range(12):
            sched.submit(req(i, plen=21, max_new=4))
        tokens, finished = drive(sched)
        assert len(finished) == 12
        # burst schedule: whole-prompt admission spikes + 1-token steps
        bursts = []
        for i in range(12):
            bursts.append(21)
            bursts.extend([1, 1, 1])
        assert tokens_per_step_cov(tokens) < tokens_per_step_cov(bursts)


class TestPreemption:
    def test_block_exhaustion_preempts_youngest_and_resumes(self):
        # pool of 6 allocatable blocks (24 tokens), two lanes; each request
        # needs 4 blocks at full length -> they cannot both finish resident
        sched, kv = make_sched(slots=2, chunk=4, bs=4, num_blocks=7, mb=8)
        r0, r1 = req(0, plen=9, max_new=7), req(1, plen=9, max_new=7)
        sched.submit(r0)                           # 16-token padded ctx
        sched.submit(r1)
        tokens, finished = drive(sched)
        assert sorted(finished) == [0, 1]
        # the youngest request was the victim; the oldest never lost blocks
        assert r0.preemptions == 0
        assert r1.preemptions >= 1
        assert kv.blocks_in_use == 0

    def test_victim_is_youngest_and_oldest_never_preempted(self):
        sched, kv = make_sched(slots=3, chunk=4, bs=4, num_blocks=7, mb=8)
        for i in range(3):
            sched.submit(req(i, plen=13, max_new=8))
        preempted = []
        for _ in range(400):
            plan = sched.schedule()
            if plan is None:
                break
            preempted.extend(plan.preempted)
            if plan.prefill and plan.prefill.final:
                r = sched.request_at(plan.prefill.lane)
                r.produced.append(1)
                sched.to_decode(plan.prefill.lane)
                if r.remaining <= 0:
                    sched.finish(plan.prefill.lane)
            for lane in plan.decode_lanes:
                r = sched.request_at(lane)
                r.decode_pos += 1
                r.produced.append(1)
                if r.remaining <= 0:
                    sched.finish(lane)
        assert sched.pending == 0
        assert preempted, "pool pressure should have forced preemption"
        assert 0 not in preempted      # the oldest request never loses blocks

    def test_preempted_request_keeps_generated_tokens(self):
        sched, kv = make_sched(slots=2, chunk=4, bs=4, num_blocks=5, mb=8)
        sched.submit(req(0, plen=9, max_new=8))
        sched.submit(req(1, plen=9, max_new=8))
        tokens, finished = drive(sched)
        assert sorted(finished) == [0, 1]
        # drive() produced exactly max_new tokens per request despite resume
        # (finish() only fires at remaining == 0)


class TestFairness:
    def test_fcfs_no_starvation_under_saturation(self):
        """Saturating queue through a tiny pool: every request completes and
        admission follows submission order."""
        sched, _ = make_sched(slots=2, chunk=4, bs=4, num_blocks=9, mb=8)
        for i in range(10):
            sched.submit(req(i, plen=7, max_new=5))
        admitted = []
        seen = set()
        for _ in range(1000):
            plan = sched.schedule()
            if plan is None:
                break
            for r in sched.running.values():
                if r.rid not in seen and not r.preemptions:
                    seen.add(r.rid)
                    admitted.append(r.rid)
            if plan.prefill and plan.prefill.final:
                r = sched.request_at(plan.prefill.lane)
                r.produced.append(1)
                sched.to_decode(plan.prefill.lane)
                if r.remaining <= 0:
                    sched.finish(plan.prefill.lane)
            for lane in plan.decode_lanes:
                r = sched.request_at(lane)
                r.decode_pos += 1
                r.produced.append(1)
                if r.remaining <= 0:
                    sched.finish(lane)
        assert sched.pending == 0
        assert admitted == sorted(admitted)     # FCFS first admissions

    def test_submit_rejects_oversized_request(self):
        sched, _ = make_sched(slots=1, chunk=4, bs=4, mb=4)  # 16-token table
        with pytest.raises(ValueError):
            sched.submit(req(0, plen=12, max_new=8))
