"""Telemetry subsystem (repro.obs): percentile/histogram math, Chrome
trace-event round-trips, ring-buffer drop accounting, the typed bandwidth
ledger (shared step schema + HBM-byte reconciliation + retention rollup),
and the serving-engine integration contract — obs ON never changes the
token stream, obs OFF records nothing."""
import json
import math

import numpy as np
import pytest

import jax

from repro.models import registry
from repro.models import transformer as tf
from repro.obs import make_telemetry
from repro.obs.ledger import STEP_SCHEMA, BandwidthLedger, step_row
from repro.obs.metrics import (Histogram, MetricsRegistry, RequestTracker,
                               percentile)
from repro.obs.trace import (NULL_TRACE, PID_KERNEL, PID_REQUESTS,
                             PID_SERVING, TID_COMPUTE, TID_DMA,
                             TraceRecorder)
from repro.serving import DenseServingEngine, ServeConfig, ServingEngine

pytestmark = pytest.mark.tier1


# ---------------------------------------------------------------- metrics
class TestPercentile:
    def test_matches_numpy_linear_interpolation(self):
        rng = np.random.default_rng(7)
        xs = rng.normal(size=101).tolist()
        for q in (0, 1, 25, 50, 73.5, 90, 99, 100):
            assert percentile(xs, q) == pytest.approx(
                float(np.percentile(xs, q)), abs=1e-12)

    def test_edges(self):
        assert math.isnan(percentile([], 50))
        assert percentile([4.0], 99) == 4.0
        with pytest.raises(ValueError):
            percentile([1.0, 2.0], 101)


class TestHistogram:
    def test_exact_aggregates_survive_decimation(self):
        h = Histogram(max_samples=64)
        xs = list(range(1000))
        for x in xs:
            h.observe(float(x))
        s = h.summary()
        assert s["count"] == 1000
        assert s["min"] == 0.0 and s["max"] == 999.0
        assert s["mean"] == pytest.approx(np.mean(xs))
        # retained samples were decimated, never grown past the cap
        assert 0 < s["retained_samples"] <= 64
        # quantiles of the decimated reservoir still track the stream
        assert h.quantile(50) == pytest.approx(float(np.percentile(xs, 50)),
                                               rel=0.1)

    def test_quantile_exact_below_cap(self):
        h = Histogram(max_samples=64)
        for x in (5.0, 1.0, 9.0, 3.0):
            h.observe(x)
        assert h.quantile(50) == pytest.approx(
            float(np.percentile([5, 1, 9, 3], 50)))


class TestRequestTracker:
    def test_ttft_and_tpot_math(self):
        t = {"now": 0.0}
        rt = RequestTracker(MetricsRegistry(), clock=lambda: t["now"])
        rt.on_submit(0)
        t["now"] = 0.5
        rt.on_first_token(0)
        t["now"] = 0.7                # duplicate first-token (preemption
        rt.on_first_token(0)          # resume) must NOT move TTFT
        t["now"] = 2.5
        rt.on_finish(0, tokens=5)
        s = rt.summary()
        assert s["ttft"]["count"] == 1
        assert s["ttft"]["p50"] == pytest.approx(0.5)
        # (finish - first) / (tokens - 1) = (2.5 - 0.5) / 4
        assert s["tpot"]["p50"] == pytest.approx(0.5)

    def test_single_token_request_has_no_tpot(self):
        rt = RequestTracker(MetricsRegistry(), clock=lambda: 1.0)
        rt.on_submit(0)
        rt.on_first_token(0)
        rt.on_finish(0, tokens=1)
        assert rt.summary()["tpot"]["count"] == 0


# ------------------------------------------------------------------ trace
class TestTraceRecorder:
    def _fake_clock(self):
        t = {"now": 0.0}

        def clock():
            t["now"] += 0.001
            return t["now"]

        return clock

    def test_chrome_json_round_trip(self, tmp_path):
        tr = TraceRecorder(capacity=128, clock=self._fake_clock())
        tr.name_process(PID_SERVING, "serving")
        tr.complete("step", tr.now_us(), 500.0, pid=PID_SERVING, tid=0,
                    cat="step", args={"tokens": 3})
        tr.instant("admit", pid=PID_SERVING, tid=10, cat="sched")
        tr.counter("hbm", {"total": 123.0}, pid=PID_SERVING)
        tr.async_begin("req 0", 0, pid=PID_REQUESTS)
        tr.async_end("req 0", 0, pid=PID_REQUESTS)
        path = tmp_path / "trace.json"
        tr.write(str(path))
        doc = json.loads(path.read_text())
        evs = doc["traceEvents"]
        assert {e["ph"] for e in evs} == {"M", "X", "i", "C", "b", "e"}
        for e in evs:
            assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
            if e["ph"] != "M":
                assert isinstance(e["ts"], (int, float)) and e["ts"] >= 0
        x = next(e for e in evs if e["ph"] == "X")
        assert x["dur"] == 500.0 and x["args"]["tokens"] == 3
        b = next(e for e in evs if e["ph"] == "b")
        e_ = next(e for e in evs if e["ph"] == "e")
        # async spans pair on (cat, id, name)
        assert (b["id"], b["name"]) == (e_["id"], e_["name"])
        assert doc["otherData"]["dropped_events"] == 0

    def test_ring_drops_oldest_and_counts(self):
        tr = TraceRecorder(capacity=4, clock=self._fake_clock())
        tr.name_process(1, "p")       # metadata is exempt from the ring
        for i in range(10):
            tr.instant(f"e{i}", pid=1)
        assert len(tr) == 4
        assert tr.dropped == 6
        doc = tr.to_chrome()
        assert doc["otherData"]["dropped_events"] == 6
        names = [e["name"] for e in doc["traceEvents"]]
        assert "process_name" in names           # meta survived
        assert names[-4:] == ["e6", "e7", "e8", "e9"]

    def test_span_contextmanager(self):
        tr = TraceRecorder(clock=self._fake_clock())
        with tr.span("work", pid=1, tid=2, args={"k": 1}):
            pass
        (ev,) = tr.events
        assert ev["ph"] == "X" and ev["dur"] > 0 and ev["args"]["k"] == 1

    def test_null_trace_is_inert(self):
        assert not NULL_TRACE.enabled
        assert len(NULL_TRACE) == 0
        NULL_TRACE.instant("x")       # all emitters are no-ops
        NULL_TRACE.complete("x", 0.0, 1.0)
        assert len(NULL_TRACE) == 0
        with pytest.raises(RuntimeError):
            NULL_TRACE.write("/dev/null")


# ----------------------------------------------------------------- ledger
class TestLedger:
    def test_step_row_zero_fill_and_derived(self):
        row = step_row(tokens=4, param_bytes=100, kv_write_bytes=40,
                       kv_read_bytes=60, drafted_tokens=8,
                       accepted_tokens=6)
        assert set(row) == set(STEP_SCHEMA)
        assert row["hbm_bytes"] == 200          # params + writes + reads
        assert row["acceptance_rate"] == pytest.approx(0.75)
        assert row["spec_saved_bytes"] == 6 * 100
        assert row["prefill_tokens"] == 0       # unset fields zero-fill
        with pytest.raises(ValueError):
            step_row(not_a_field=1)

    def test_reconciles_with_seed_byte_formula(self):
        """Regression: the ledger's derived hbm_bytes must equal the seed
        engines' hand-built accounting, `param_bytes + tokens *
        kv_token_bytes + read_tokens * kv_token_bytes`, exactly."""
        param_bytes, kv_token_bytes = 1_000_000, 2_048
        for tokens, read_tokens in ((1, 7), (5, 123), (32, 0)):
            row = step_row(tokens=tokens, param_bytes=param_bytes,
                           kv_write_bytes=tokens * kv_token_bytes,
                           kv_read_bytes=read_tokens * kv_token_bytes)
            seed = (param_bytes + tokens * kv_token_bytes
                    + read_tokens * kv_token_bytes)
            assert row["hbm_bytes"] == seed

    def test_retention_rollup_keeps_lifetime_totals(self):
        led = BandwidthLedger(retention=4)
        for i in range(10):
            led.record(tokens=i, param_bytes=100)
        assert len(led) == 4                    # ring held at retention
        assert led.steps == 10
        assert led.rolled_up_steps == 6
        assert [r["step"] for r in led] == [6, 7, 8, 9]
        assert led.total("tokens") == sum(range(10))
        assert led.total("hbm_bytes") == 10 * 100
        s = led.summary()
        assert s["total_tokens"] == 45 and s["rolled_up_steps"] == 6
        # list compatibility the engines' callers rely on
        assert led[0]["step"] == 6 and len(led[-2:]) == 2

    def test_unbounded_by_default(self):
        led = BandwidthLedger()
        for _ in range(100):
            led.record(tokens=1)
        assert len(led) == 100 and led.rolled_up_steps == 0

    def test_utilization_report_shape(self):
        led = BandwidthLedger()
        rng = np.random.default_rng(0)
        for _ in range(8):
            led.record(tokens=2, param_bytes=1000,
                       kv_write_bytes=int(rng.integers(50, 80)),
                       kv_read_bytes=int(rng.integers(100, 300)))
        rep = led.utilization_report()
        assert 0 < rep["measured_bw_utilization"] <= 1
        assert 0 < rep["predicted_bw_utilization"] <= 1
        assert rep["steps_measured"] == 8


# ----------------------------------------------------- engine integration
@pytest.fixture(scope="module")
def qwen():
    cfg = registry.get_config("qwen1.5-0.5b", smoke=True)
    return cfg, tf.init_params(cfg, jax.random.PRNGKey(0))


def _prompts(cfg, n=3):
    rng = np.random.default_rng(0)
    return [rng.integers(0, cfg.vocab_size, size=l).tolist()
            for l in (5, 11, 3)[:n]]


def _run(engine_cls, cfg, params, obs, **kw):
    eng = engine_cls(cfg, params,
                     ServeConfig(slots=2, max_len=64, obs=obs, **kw))
    rids = [eng.submit(p, max_new_tokens=6) for p in _prompts(cfg)]
    res = eng.run()
    return [res[r] for r in rids], eng


class TestEngineIntegration:
    @pytest.mark.parametrize("engine_cls", (ServingEngine,
                                            DenseServingEngine))
    def test_obs_never_changes_tokens(self, qwen, engine_cls):
        cfg, params = qwen
        off, eng_off = _run(engine_cls, cfg, params, obs=False)
        on, eng_on = _run(engine_cls, cfg, params, obs=True)
        assert on == off
        # disabled path recorded nothing and spent no wall-clock calls
        assert len(eng_off.obs.trace) == 0
        assert all(m["step_wall_us"] == 0 for m in eng_off.metrics)
        assert len(eng_on.obs.trace) > 0
        assert all(m["step_wall_us"] > 0 for m in eng_on.metrics)

    def test_engines_share_one_step_schema(self, qwen):
        """The satellite contract: dense rows are no longer hand-synced
        parity zeros — both engines emit exactly STEP_SCHEMA."""
        cfg, params = qwen
        _, paged = _run(ServingEngine, cfg, params, obs=False)
        _, dense = _run(DenseServingEngine, cfg, params, obs=False)
        for eng in (paged, dense):
            assert eng.metrics, "engine recorded no steps"
            for row in eng.metrics:
                assert set(row) == set(STEP_SCHEMA)
        # dense byte columns are real measurements now, not parity zeros
        assert dense.metrics.total("param_bytes") > 0
        assert dense.metrics.total("kv_read_bytes") > 0

    @pytest.mark.parametrize("engine_cls", (ServingEngine,
                                            DenseServingEngine))
    def test_ledger_rows_reconcile(self, qwen, engine_cls):
        cfg, params = qwen
        _, eng = _run(engine_cls, cfg, params, obs=False)
        for m in eng.metrics:
            assert m["hbm_bytes"] == (m["param_bytes"] + m["kv_write_bytes"]
                                      + m["kv_read_bytes"])

    def test_trace_covers_requests_steps_and_kernel_lanes(self, qwen):
        cfg, params = qwen
        streams, eng = _run(ServingEngine, cfg, params, obs=True)
        evs = eng.obs.trace.events
        assert any(e["ph"] == "X" and e["pid"] == PID_SERVING
                   and e["name"] == "step" for e in evs)
        begins = [e for e in evs if e["ph"] == "b" and e["pid"] == PID_REQUESTS]
        ends = [e for e in evs if e["ph"] == "e" and e["pid"] == PID_REQUESTS]
        assert len(begins) == len(streams) and len(ends) == len(streams)
        kernel_tids = {e["tid"] for e in evs
                       if e["pid"] == PID_KERNEL and e["ph"] == "X"}
        assert {TID_DMA, TID_COMPUTE} <= kernel_tids   # both modeled lanes
        # trace JSON is loadable end-to-end
        doc = json.loads(json.dumps(eng.obs.trace.to_chrome()))
        assert doc["traceEvents"]
        ttft = eng.obs.requests.summary()["ttft"]
        assert ttft["count"] == len(streams)
        assert math.isfinite(ttft["p50"]) and ttft["p50"] > 0

    def test_metrics_retention_knob_reaches_engine(self, qwen):
        cfg, params = qwen
        _, full = _run(ServingEngine, cfg, params, obs=False)
        _, eng = _run(ServingEngine, cfg, params, obs=False,
                      metrics_retention=2)
        assert len(eng.metrics) == 2
        assert eng.metrics.steps > 2                   # rollup happened
        # totals stay lifetime-exact: identical workload, identical sums
        assert eng.metrics.totals() == full.metrics.totals()

    def test_telemetry_factory(self):
        t_on = make_telemetry(True, trace_capacity=8)
        t_off = make_telemetry(False)
        assert t_on.enabled and t_on.trace.enabled
        assert not t_off.enabled and t_off.trace is NULL_TRACE
        with pytest.raises(RuntimeError):
            t_off.write_metrics("/dev/null")
