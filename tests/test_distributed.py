"""Distributed-behaviour tests.

These need multiple devices, so each runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count set there — the main pytest
process keeps the default single device (smoke tests must not see 512).

Meshes come from `repro.launch.mesh.make_mesh_compat` / `mesh_context`, so
the same tests run on the post-0.4.x explicit-mesh API
(`jax.sharding.AxisType`, `jax.set_mesh`) AND on jax 0.4.x (plain
`jax.make_mesh` + the Mesh context manager).  Explicit `NamedSharding`s
carry the mesh everywhere it matters; paths that detect the ambient mesh
through the new-API registry (shard_map context parallelism, MoE explicit
schedules) degrade to their single-program equivalents on 0.4.x, which these
tests treat as numerically-identical fallbacks, not failures.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

import jax

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=REPO,
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-3000:]}"
    return proc.stdout


class TestStreamerDistributed:
    def test_modes_match_and_emit_expected_collectives(self):
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np, re
            from jax.sharding import PartitionSpec as P, NamedSharding
            from repro.core.streamer import stream_layers, StreamSettings
            from repro.launch.mesh import make_mesh_compat, mesh_context

            mesh = make_mesh_compat((4, 2), ("data", "model"))
            L, D, F, B = 6, 64, 128, 8
            key = jax.random.PRNGKey(0)
            ws = {"w1": jax.random.normal(key, (L, D, F)) * 0.05,
                  "w2": jax.random.normal(key, (L, F, D)) * 0.05}
            x = jax.random.normal(key, (B, D))
            shard = {"w1": P("data", None), "w2": P(None, "data")}
            full = {"w1": P(None, None), "w2": P(None, None)}
            ws_sh = {"w1": NamedSharding(mesh, P(None, "data", None)),
                     "w2": NamedSharding(mesh, P(None, None, "data"))}
            x_sh = NamedSharding(mesh, P("data", None))

            def apply_fn(x, w):
                return x + jnp.tanh(x @ w["w1"]) @ w["w2"]

            outs, ags = {}, {}
            with mesh_context(mesh):
                for mode in ("resident", "insitu", "naive_pp", "gpp"):
                    f = jax.jit(lambda x, ws, m=mode: stream_layers(
                        apply_fn, x, ws, L,
                        settings=StreamSettings(mode=m, ring_depth=3),
                        mesh=mesh, shard_specs=shard, full_specs=full),
                        in_shardings=(x_sh, ws_sh))
                    outs[mode] = np.asarray(f(x, ws))
                    txt = f.lower(x, ws).compile().as_text()
                    ags[mode] = len(re.findall(r"all-gather", txt))
            for m in ("insitu", "naive_pp", "gpp"):
                np.testing.assert_allclose(outs[m], outs["resident"],
                                           rtol=1e-5, atol=1e-5)
            # gpp must emit chunked gathers: more, smaller all-gather ops
            assert ags["gpp"] > ags["naive_pp"] >= ags["insitu"] > 0, ags
            print("OK", ags)
        """)
        assert "OK" in out

    def test_gpp_training_gradients(self):
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P
            from repro.core.streamer import stream_layers, StreamSettings
            from repro.launch.mesh import make_mesh_compat, mesh_context
            mesh = make_mesh_compat((4, 2), ("data", "model"))
            L, D, F, B = 5, 32, 64, 4
            key = jax.random.PRNGKey(1)
            ws = {"w": jax.random.normal(key, (L, D, D)) * 0.1}
            x = jax.random.normal(key, (B, D))
            shard = {"w": P("data", None)}
            full = {"w": P(None, None)}
            def apply_fn(c, w):
                return jnp.tanh(c @ w["w"])
            def loss(ws, mode):
                y = stream_layers(apply_fn, x, ws, L,
                                  settings=StreamSettings(mode=mode, ring_depth=4),
                                  mesh=mesh, shard_specs=shard, full_specs=full)
                return (y ** 2).mean()
            with mesh_context(mesh):
                g_res = jax.jit(jax.grad(loss), static_argnums=1)(ws, "resident")
                g_gpp = jax.jit(jax.grad(loss), static_argnums=1)(ws, "gpp")
            np.testing.assert_allclose(np.asarray(g_gpp["w"]),
                                       np.asarray(g_res["w"]), rtol=1e-4, atol=1e-5)
            print("OK")
        """)
        assert "OK" in out


class TestContextParallelAttention:
    def test_cp_matches_reference_and_grads(self):
        """Heads not divisible by TP -> shard_map context parallelism must be
        numerically identical to the single-device path (incl. gradients)."""
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.models import attention as A
            from repro.models.layers import init_from_specs
            from repro.launch.mesh import make_mesh_compat, mesh_context

            mesh = make_mesh_compat((2, 4), ("data", "model"))
            cfg = A.AttnConfig(d_model=48, num_heads=6, num_kv_heads=2,
                               head_dim=8, dtype=jnp.float32)
            p = init_from_specs(A.attn_specs(cfg), jax.random.PRNGKey(0))
            x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 48)) * 0.5
            pos = jnp.broadcast_to(jnp.arange(64)[None], (4, 64))
            ref = A.gqa_forward(p, cfg, x, pos)
            with mesh_context(mesh):
                outp = jax.jit(lambda p, x: A.gqa_forward(p, cfg, x, pos))(p, x)
            np.testing.assert_allclose(np.asarray(outp), np.asarray(ref),
                                       rtol=2e-4, atol=2e-4)
            def loss(p, x):
                return (A.gqa_forward(p, cfg, x, pos) ** 2).mean()
            g_ref = jax.grad(loss)(p, x)
            with mesh_context(mesh):
                g_cp = jax.jit(jax.grad(loss))(p, x)
            np.testing.assert_allclose(np.asarray(g_cp["w_q"]),
                                       np.asarray(g_ref["w_q"]),
                                       rtol=1e-3, atol=1e-4)
            print("OK")
        """)
        assert "OK" in out

    def test_cp_with_sliding_window(self):
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.models import attention as A
            from repro.models.layers import init_from_specs
            from repro.launch.mesh import make_mesh_compat, mesh_context
            mesh = make_mesh_compat((1, 4), ("data", "model"))
            cfg = A.AttnConfig(d_model=24, num_heads=3, num_kv_heads=1,
                               head_dim=8, window=16, dtype=jnp.float32)
            p = init_from_specs(A.attn_specs(cfg), jax.random.PRNGKey(0))
            x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 24)) * 0.5
            pos = jnp.broadcast_to(jnp.arange(64)[None], (2, 64))
            ref = A.gqa_forward(p, cfg, x, pos)
            with mesh_context(mesh):
                outp = jax.jit(lambda p, x: A.gqa_forward(p, cfg, x, pos))(p, x)
            np.testing.assert_allclose(np.asarray(outp), np.asarray(ref),
                                       rtol=2e-4, atol=2e-4)
            print("OK")
        """, devices=4)
        assert "OK" in out


class TestMoEShardMap:
    def test_moe_shard_map_matches_local(self):
        """The explicit-schedule MoE (shard_map over data x model) must equal
        the local grouped-dispatch path, including gradients."""
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.models import moe as M
            from repro.models.layers import init_from_specs
            from repro.launch.mesh import make_mesh_compat, mesh_context

            cfg = M.MoeConfig(d_model=32, d_ff=16, num_experts=8,
                              experts_per_token=2, capacity_factor=8.0,
                              dtype=jnp.float32, dispatch_groups=4)
            p = init_from_specs(M.moe_specs(cfg), jax.random.PRNGKey(0))
            x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32)) * 0.5

            ref = M.moe_apply(p, cfg, x)          # no mesh -> local path
            mesh = make_mesh_compat((4, 2), ("data", "model"))
            wsh = {
                "router": NamedSharding(mesh, P(None, None)),
                "w_gate": NamedSharding(mesh, P("model", "data", None)),
                "w_up": NamedSharding(mesh, P("model", "data", None)),
                "w_down": NamedSharding(mesh, P("model", "data", None)),
            }
            psh = {k: wsh[k] for k in p}
            p_dev = jax.device_put(p, psh)
            x_dev = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
            with mesh_context(mesh):
                got = jax.jit(lambda p, x: M.moe_apply(p, cfg, x))(p_dev, x_dev)
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       rtol=1e-4, atol=1e-4)

            def loss(p, x):
                return (M.moe_apply(p, cfg, x) ** 2).mean()
            g_ref = jax.grad(loss)(p, x)
            with mesh_context(mesh):
                g = jax.jit(jax.grad(loss))(p_dev, x_dev)
            for k in ("router", "w_gate", "w_down"):
                np.testing.assert_allclose(np.asarray(g[k]),
                                           np.asarray(g_ref[k]),
                                           rtol=1e-3, atol=1e-4)
            print("OK")
        """)
        assert "OK" in out


class TestStepsOnHostMesh:
    def test_train_step_lowers_and_runs(self):
        out = run_py("""
            import jax, jax.numpy as jnp
            from repro.configs.base import ShapeConfig
            from repro.launch.mesh import make_host_mesh, mesh_context
            from repro.launch.steps import make_train_step
            from repro.models import registry, transformer as tf
            from repro.optim import adamw

            cfg = registry.get_config("gemma3-12b", smoke=True)
            mesh = make_host_mesh(2, 2)
            shape = ShapeConfig("t", 64, 8, "train")
            with mesh_context(mesh):
                b = make_train_step(cfg, mesh, shape)
                params = jax.device_put(tf.init_params(cfg, jax.random.PRNGKey(0)),
                                        b.arg_shardings[0])
                opt = jax.device_put(adamw.adamw_init(params), b.arg_shardings[1])
                import numpy as np
                batch = {"tokens": jnp.zeros((8, 64), jnp.int32),
                         "labels": jnp.ones((8, 64), jnp.int32)}
                batch = {k: jax.device_put(v, b.arg_shardings[2][k])
                         for k, v in batch.items()}
                params, opt, m = b.fn(params, opt, batch, jnp.asarray(0))
                assert np.isfinite(float(m["loss"]))
                print("OK", float(m["loss"]))
        """, devices=4)
        assert "OK" in out

    def test_decode_step_with_seq_sharded_cache(self):
        """long-context B=1 decode: cache must shard on sequence length."""
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs.base import ShapeConfig
            from repro.launch.mesh import make_host_mesh, mesh_context
            from repro.launch.steps import make_decode_step
            from repro.models import registry, transformer as tf

            cfg = registry.get_config("h2o-danube-1.8b", smoke=True)
            mesh = make_host_mesh(2, 2)
            shape = ShapeConfig("long", 64, 1, "decode")  # B=1 < dp size
            with mesh_context(mesh):
                b = make_decode_step(cfg, mesh, shape)
                lowered = b.fn.lower(*b.input_specs)
                compiled = lowered.compile()
                print("OK", compiled.memory_analysis().temp_size_in_bytes)
        """, devices=4)
        assert "OK" in out

    def test_streaming_train_step_gpp_mode(self):
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs.base import ShapeConfig
            from repro.core.streamer import StreamSettings
            from repro.launch.mesh import make_host_mesh, mesh_context
            from repro.launch.steps import make_train_step
            from repro.models import registry, transformer as tf
            from repro.optim import adamw

            cfg = registry.get_config("qwen2-7b", smoke=True).with_(
                stream=StreamSettings(mode="gpp", ring_depth=3))
            mesh = make_host_mesh(2, 2)
            shape = ShapeConfig("t", 64, 8, "train")
            with mesh_context(mesh):
                b = make_train_step(cfg, mesh, shape)
                params = jax.device_put(tf.init_params(cfg, jax.random.PRNGKey(0)),
                                        b.arg_shardings[0])
                opt = jax.device_put(adamw.adamw_init(params), b.arg_shardings[1])
                batch = {"tokens": jnp.zeros((8, 64), jnp.int32),
                         "labels": jnp.ones((8, 64), jnp.int32)}
                batch = {k: jax.device_put(v, b.arg_shardings[2][k])
                         for k, v in batch.items()}
                params, opt, m = b.fn(params, opt, batch, jnp.asarray(0))
                assert np.isfinite(float(m["loss"]))
                print("OK")
        """, devices=4)
        assert "OK" in out


class TestTrainDriver:
    def test_cli_train_and_resume(self, tmp_path):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
               "qwen1.5-0.5b", "--smoke", "--batch", "4", "--seq", "32",
               "--devices", "4", "--mesh", "2x2",
               "--ckpt-dir", str(tmp_path), "--ckpt-every", "3"]
        p1 = subprocess.run(cmd + ["--steps", "6"], capture_output=True,
                            text=True, timeout=600, env=env, cwd=REPO)
        assert p1.returncode == 0, p1.stderr[-2000:]
        p2 = subprocess.run(cmd + ["--steps", "9"], capture_output=True,
                            text=True, timeout=600, env=env, cwd=REPO)
        assert p2.returncode == 0, p2.stderr[-2000:]
        assert "resumed from step 6" in p2.stdout
