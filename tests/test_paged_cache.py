"""Paged KV cache: block allocator, tables, defragmentation."""
import numpy as np
import pytest

from repro.serving.cache import BlockAllocator, PagedKVCache

pytestmark = pytest.mark.tier1


class TestBlockAllocator:
    def test_capacity_excludes_null_block(self):
        a = BlockAllocator(8)
        assert a.capacity == 7
        assert a.num_free == 7

    def test_all_or_nothing(self):
        a = BlockAllocator(4)
        assert a.allocate(3) is not None
        assert a.num_free == 0
        assert a.allocate(1) is None          # empty: no partial grant
        a.free([1])
        assert a.allocate(2) is None          # 1 free < 2 wanted
        got = a.allocate(1)
        assert got == [1]

    def test_never_hands_out_null_block(self):
        a = BlockAllocator(16)
        got = a.allocate(15)
        assert 0 not in got
        assert sorted(got) == list(range(1, 16))

    def test_double_free_rejected(self):
        a = BlockAllocator(4)
        got = a.allocate(2)
        a.free(got)
        with pytest.raises(ValueError):
            a.free([got[0]])


class TestPagedKVCache:
    def kv(self, num_blocks=9, bs=4, mb=8, slots=2):
        return PagedKVCache(slots=slots, num_blocks=num_blocks, block_size=bs,
                            max_blocks_per_seq=mb)

    def test_ensure_maps_blocks_on_demand(self):
        kv = self.kv()
        assert kv.ensure(0, 0)                # first token: one block
        assert kv.num_mapped[0] == 1
        assert kv.ensure(0, 3)                # still inside block 0
        assert kv.num_mapped[0] == 1
        assert kv.ensure(0, 4)                # crosses into block 1
        assert kv.num_mapped[0] == 2
        assert kv.blocks_in_use == 2
        # table prefix is mapped to distinct non-null physical blocks
        blocks = kv.blocks_for(0)
        assert len(set(blocks)) == 2 and 0 not in blocks

    def test_capacity_is_shared_not_per_lane(self):
        # 8 allocatable blocks, 2 lanes: one lane may hold 6 while the other
        # holds 2 — the dense engine would have reserved 4+4
        kv = self.kv(num_blocks=9)
        assert kv.ensure(0, 23)               # 6 blocks
        assert kv.ensure(1, 7)                # 2 blocks
        assert kv.blocks_in_use == 8
        assert not kv.ensure(1, 11)           # pool exhausted
        kv.free_lane(0)
        assert kv.ensure(1, 11)

    def test_free_lane_resets_table(self):
        kv = self.kv()
        kv.ensure(0, 10)
        kv.free_lane(0)
        assert kv.blocks_in_use == 0
        assert (kv.tables[0] == 0).all()
        assert kv.num_mapped[0] == 0

    def test_overflow_raises(self):
        kv = self.kv(num_blocks=32, mb=4)
        with pytest.raises(ValueError):
            kv.ensure(0, 4 * 4)               # past the block table

    def test_defragment_compacts_and_preserves_mapping(self):
        kv = self.kv(num_blocks=12, slots=3)
        kv.ensure(0, 7)                       # 2 blocks
        kv.ensure(1, 11)                      # 3 blocks
        kv.ensure(2, 3)                       # 1 block
        kv.free_lane(1)                       # punch a hole mid-pool
        # simulate a pool: pool[b] == original physical id
        pool = np.arange(12)
        before = {lane: [pool[b] for b in kv.blocks_for(lane)]
                  for lane in (0, 2)}
        perm = kv.defragment()
        new_pool = pool[perm]
        after = {lane: [new_pool[b] for b in kv.blocks_for(lane)]
                 for lane in (0, 2)}
        assert before == after                # contents follow the remap
        # live blocks are now the dense prefix 1..3
        live = sorted(b for lane in (0, 2) for b in kv.blocks_for(lane))
        assert live == [1, 2, 3]
        assert perm[0] == 0                   # null block pinned
        # allocator reflects the compaction
        assert kv.blocks_in_use == 3
        assert kv.ensure(1, 31)               # all 8 remaining blocks fit


class TestWindowReclamation:
    """release_expired: blocks wholly behind the sliding-window horizon go
    back to the allocator; the zeroed table entries read the (masked) null
    block and every other cache operation tolerates them."""

    def kv(self, num_blocks=16, slots=2, bs=4, mb=8):
        return PagedKVCache(slots=slots, num_blocks=num_blocks, block_size=bs,
                            max_blocks_per_seq=mb)

    def test_expired_blocks_freed_and_zeroed(self):
        kv = self.kv()
        kv.ensure(0, 19)                      # blocks 0..4 mapped (bs=4)
        assert kv.blocks_in_use == 5
        # horizon 8, next query at 19: visible start = 12 -> blocks 0..2 dead
        freed = kv.release_expired(0, 19, 8)
        assert freed == 3
        assert kv.blocks_in_use == 2
        assert (kv.tables[0, :3] == 0).all() and (kv.tables[0, 3:5] != 0).all()
        # monotone: calling again at the same position frees nothing
        assert kv.release_expired(0, 19, 8) == 0

    def test_plateau_under_decode_growth(self):
        """Mapping ahead while releasing behind holds live blocks constant."""
        kv = self.kv(num_blocks=6, mb=32)     # 5 allocatable, 128-token table
        horizon, bs = 8, 4
        for pos in range(0, 100):
            assert kv.ensure(0, pos), f"pool dry at pos {pos}"
            kv.release_expired(0, pos, horizon)
            assert kv.blocks_in_use <= 3      # ceil(8/4) + the write block
        assert kv.num_mapped[0] == 25         # logical high-water keeps growing

    def test_free_lane_and_blocks_needed_after_release(self):
        kv = self.kv()
        kv.ensure(0, 19)
        kv.release_expired(0, 19, 8)
        assert kv.blocks_needed(0, 23) == 1   # high-water advances normally
        kv.free_lane(0)                       # must skip the zeroed entries
        assert kv.blocks_in_use == 0
        assert kv.released[0] == 0

    def test_defragment_after_release(self):
        kv = self.kv(slots=2)
        kv.ensure(0, 19)
        kv.ensure(1, 7)
        kv.release_expired(0, 19, 8)
        pool = np.arange(16)
        before = {l: [pool[b] for b in kv.blocks_for(l)] for l in (0, 1)}
        new_pool = pool[kv.defragment()]
        after = {l: [new_pool[b] for b in kv.blocks_for(l)] for l in (0, 1)}
        assert before == after
        assert kv.blocks_in_use == 4

    def test_bad_horizon_rejected(self):
        with pytest.raises(ValueError):
            self.kv().release_expired(0, 10, 0)
