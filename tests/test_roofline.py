"""Unit tests for the roofline HLO analysis (launch/roofline.py) — the
loop-aware parsers are load-bearing for §Roofline, so they get synthetic-HLO
ground truth here."""
import textwrap

from repro.launch import roofline as rl

# A synthetic optimized-HLO module: an entry with a while loop whose body
# (known_trip_count=4) contains an all-gather and a dot, plus a nested loop
# (trip 2) with an all-reduce.
HLO = textwrap.dedent("""
    HloModule jit_step, entry_computation_layout={(f32[8,16])->f32[8,16]}

    %inner.body (p0: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %p0 = (s32[], f32[8,16]) parameter(0)
      %x1 = f32[8,16]{1,0} get-tuple-element(%p0), index=1
      %ar = f32[8,16]{1,0} all-reduce(%x1), replica_groups={}, to_apply=%add
      ROOT %t = (s32[], f32[8,16]) tuple(%c, %ar)
    }

    %outer.body (p1: (s32[], f32[8,16], f32[16,32])) -> (s32[], f32[8,16], f32[16,32]) {
      %p1 = (s32[], f32[8,16], f32[16,32]) parameter(0)
      %a = f32[8,16]{1,0} get-tuple-element(%p1), index=1
      %w = f32[16,32]{1,0} get-tuple-element(%p1), index=2
      %ag = f32[8,16]{1,0} all-gather(%a), dimensions={0}
      %d = f32[8,32]{1,0} dot(%ag, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %wh = (s32[], f32[8,16]) while(%init), condition=%cond, body=%inner.body, backend_config={"known_trip_count":{"n":"2"}}
      ROOT %t2 = (s32[], f32[8,16], f32[16,32]) tuple(%c2, %ag, %w)
    }

    ENTRY %main (arg: f32[8,16]) -> f32[8,16] {
      %arg = f32[8,16]{1,0} parameter(0)
      %w0 = f32[16,32]{1,0} constant(0)
      %loop = (s32[], f32[8,16], f32[16,32]) while(%init2), condition=%cond2, body=%outer.body, backend_config={"known_trip_count":{"n":"4"}}
      %ag0 = f32[8,16]{1,0} all-gather(%arg), dimensions={0}
      ROOT %out = f32[8,16]{1,0} get-tuple-element(%loop), index=1
    }
""")


class TestLoopAwareParsers:
    def test_multipliers(self):
        comps = rl._parse_computations(HLO)
        assert set(comps) >= {"inner.body", "outer.body", "main"}
        mults = rl._loop_multipliers(HLO, comps, default_layers=99)
        assert mults["main"] == 1
        assert mults["outer.body"] == 4          # known_trip_count 4
        assert mults["inner.body"] == 8          # 4 x 2 nested

    def test_collective_bytes(self):
        colls = rl.collective_bytes_loop_aware(HLO, default_layers=99)
        f32_8x16 = 8 * 16 * 4
        # all-gather: 1x in entry + 4x in outer body = 5 executions
        assert colls["all-gather"]["count"] == 5
        assert colls["all-gather"]["bytes"] == 5 * f32_8x16
        # all-reduce: 8 executions (nested)
        assert colls["all-reduce"]["count"] == 8
        assert colls["all-reduce"]["bytes"] == 8 * f32_8x16

    def test_dot_flops_and_bytes(self):
        flops, nbytes, cov = rl.dot_stats_loop_aware(HLO, default_layers=99)
        assert cov == 1.0
        # dot: (8,16)x(16,32) -> 2*8*32*16 flops, x4 loop executions
        assert flops == 4 * 2 * 8 * 32 * 16
        # operand bytes assume 2B storage + f32 output from the line
        expect_operands = 2 * (8 * 16) + 2 * (16 * 32)
        expect_out = 4 * (8 * 32)
        assert nbytes == 4 * (expect_operands + expect_out)

    def test_default_layers_fallback(self):
        """A while without known_trip_count gets the default multiplier."""
        hlo = HLO.replace('backend_config={"known_trip_count":{"n":"4"}}', "")
        comps = rl._parse_computations(hlo)
        mults = rl._loop_multipliers(hlo, comps, default_layers=7)
        assert mults["outer.body"] == 7

    def test_tensor_bytes(self):
        assert rl._tensor_bytes("bf16[4,8]") == 64
        assert rl._tensor_bytes("f32[2,2] bf16[2]") == 20
        assert rl._tensor_bytes("pred[16]") == 16


class TestModelFlops:
    def test_train_vs_decode(self):
        from repro.configs.base import SHAPES
        from repro.models import registry
        cfg = registry.get_config("qwen1.5-0.5b")
        t = rl.model_flops(cfg, SHAPES["train_4k"])
        d = rl.model_flops(cfg, SHAPES["decode_32k"])
        n = cfg.active_params()
        assert t == 6.0 * n * 4096 * 256
        assert d == 2.0 * n * 128
