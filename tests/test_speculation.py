"""Speculative decoding in the paged engine: self-drafting (prompt-lookup
n-grams + prefix radix tree), the batched verify step, GPP verify budgeting,
rollback safety of rejected drafts, and exactness — greedy AND temperature
streams must be token-for-token identical with speculation on or off."""
import numpy as np
import pytest

import jax

from repro.core.schedule import plan_verify_budget
from repro.models import registry
from repro.models import transformer as tf
from repro.serving import DenseServingEngine, ServeConfig, ServingEngine
from repro.serving.cache import PagedKVCache
from repro.serving.prefix import ngram_propose

pytestmark = pytest.mark.tier1

PARITY_ARCHS = ("qwen1.5-0.5b", "gemma3-12b", "deepseek-v2-lite-16b")


@pytest.fixture(scope="module")
def setups():
    out = {}
    for arch in PARITY_ARCHS:
        cfg = registry.get_config(arch, smoke=True)
        out[arch] = (cfg, tf.init_params(cfg, jax.random.PRNGKey(0)))
    return out


def _spec_prompts(cfg):
    """Mixed lengths; two repetitive prompts so self-drafting fires and one
    short irregular prompt so some steps carry no drafts (plain decode)."""
    v = cfg.vocab_size
    return [
        np.tile([5 % v, 6 % v, 7 % v, 8 % v], 6).tolist(),
        [1 % v, 2 % v, 3 % v],
        np.tile([9 % v, 3 % v], 10).tolist(),
    ]


def _run(cfg, params, *, speculation, prompts, max_new=24, draft_model=None,
         **kw):
    serve = ServeConfig(slots=2, max_len=128, speculation=speculation,
                        draft_len=4 if speculation else 0, **kw)
    eng = ServingEngine(cfg, params, serve, draft_model=draft_model)
    for p in prompts:
        eng.submit(p, max_new_tokens=max_new)
    return eng, eng.run()


# --------------------------------------------------------------- drafting
class TestNgramPropose:
    def test_longest_ngram_continuation(self):
        toks = np.array([5, 6, 7, 8, 5, 6, 7, 8, 5, 6, 7], np.int32)
        # trailing trigram [5,6,7] matched at the start -> continues 8,5,6
        assert ngram_propose(toks, 3).tolist() == [8, 5, 6]

    def test_k_truncates(self):
        toks = np.tile([9, 3], 8).astype(np.int32)
        assert ngram_propose(toks, 1).tolist() == [9]

    def test_last_occurrence_wins(self):
        # [1,2] occurs twice with different continuations; the most recent
        # one (->7) is the better local predictor
        toks = np.array([1, 2, 5, 0, 1, 2, 7, 0, 1, 2], np.int32)
        assert ngram_propose(toks, 1).tolist() == [7]

    def test_no_match_and_short_history_return_empty(self):
        assert len(ngram_propose(np.arange(12, dtype=np.int32), 4)) == 0
        assert len(ngram_propose(np.array([3], np.int32), 4)) == 0
        assert len(ngram_propose(np.zeros((0,), np.int32), 4)) == 0

    def test_never_proposes_past_history(self):
        # window excludes the trailing n-gram itself, so a match always has
        # at least one continuation token
        toks = np.array([4, 4], np.int32)
        d = ngram_propose(toks, 4)
        assert d.tolist() == [4] * len(d)


class TestSuffixLookup:
    def test_cross_request_repetition(self, setups):
        cfg, params = setups["qwen1.5-0.5b"]
        prompt = list(range(1, 17))
        eng, _ = _run(cfg, params, speculation=False, prompts=[prompt],
                      max_new=4, prefix_cache=True)
        assert eng.prefix is not None and eng.prefix.blocks_held > 0
        # a NEW request whose context ends mid-way through the stored
        # sequence gets the stored continuation as its draft
        ctx = np.asarray(prompt[:6], np.int32)
        d = eng.prefix.suffix_lookup(ctx, 4)
        assert d.tolist() == prompt[6:10]
        # unseen context: no draft
        assert len(eng.prefix.suffix_lookup(
            np.array([900, 901, 902], np.int32), 4)) == 0


# ---------------------------------------------------------------- budget
class TestVerifyBudget:
    def test_slack_is_budget_minus_scheduled(self):
        assert plan_verify_budget(token_budget=12, prefill_tokens=6,
                                  decode_lanes=4) == 2
        assert plan_verify_budget(token_budget=8, prefill_tokens=8,
                                  decode_lanes=0) == 0

    def test_never_negative(self):
        assert plan_verify_budget(token_budget=4, prefill_tokens=8,
                                  decode_lanes=2) == 0

    def test_validates(self):
        with pytest.raises(ValueError):
            plan_verify_budget(token_budget=-1, prefill_tokens=0,
                               decode_lanes=0)
        with pytest.raises(ValueError):
            plan_verify_budget(token_budget=4, prefill_tokens=-1,
                               decode_lanes=0)


# -------------------------------------------------------------- rollback
class TestTruncateBlocks:
    def kv(self):
        return PagedKVCache(slots=2, num_blocks=9, block_size=4,
                            max_blocks_per_seq=8)

    def test_frees_tail_blocks(self):
        kv = self.kv()
        assert kv.ensure(0, 11)               # 3 blocks mapped
        used = kv.blocks_in_use
        freed = kv.truncate_blocks(0, 1)
        assert freed == 2
        assert kv.num_mapped[0] == 1
        assert kv.blocks_in_use == used - 2
        assert kv.tables[0, 1:].tolist() == [0] * 7
        kv.check_invariants()

    def test_keep_all_is_noop(self):
        kv = self.kv()
        assert kv.ensure(0, 7)
        assert kv.truncate_blocks(0, 2) == 0
        assert kv.truncate_blocks(0, 5) == 0
        assert kv.num_mapped[0] == 2

    def test_rejects_negative(self):
        with pytest.raises(ValueError):
            self.kv().truncate_blocks(0, -1)


# ---------------------------------------------------------------- parity
class TestSpeculationParity:
    @pytest.mark.parametrize("arch", PARITY_ARCHS)
    def test_greedy_stream_identical_on_vs_off(self, setups, arch):
        cfg, params = setups[arch]
        prompts = _spec_prompts(cfg)
        on, r_on = _run(cfg, params, speculation=True, prompts=prompts)
        off, r_off = _run(cfg, params, speculation=False, prompts=prompts)
        assert r_on == r_off
        on.kv.check_invariants()
        drafted = sum(m["drafted_tokens"] for m in on.metrics)
        assert drafted > 0                    # speculation actually engaged
        assert on.trace_counts["verify"] == 1

    def test_temperature_stream_identical_on_vs_off(self, setups):
        cfg, params = setups["qwen1.5-0.5b"]
        prompts = _spec_prompts(cfg)
        _, r_on = _run(cfg, params, speculation=True, prompts=prompts,
                       temperature=0.7, seed=3)
        _, r_off = _run(cfg, params, speculation=False, prompts=prompts,
                        temperature=0.7, seed=3)
        # sampling keys on (seed, rid, logical token index), not on which
        # step shape produced the token — accepted verify bursts draw the
        # same samples plain decode would have
        assert r_on == r_off

    def test_three_step_shapes_compile_once(self, setups):
        """The whole point of the batched verify design: mixed prompt
        lengths, draft lengths 0..draft_len, and partial/full rejection all
        ride exactly THREE jitted shapes (chunk prefill, decode, verify)."""
        cfg, params = setups["qwen1.5-0.5b"]
        lengths = (4, 9, 24, 5, 17, 3)
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab_size, size=l).tolist()
                   for l in lengths]
        prompts[2] = np.tile([5, 6, 7, 8], 6).tolist()  # draft-friendly
        serve = ServeConfig(slots=2, max_len=128, speculation=True,
                            draft_len=4)
        eng = ServingEngine(cfg, params, serve)
        # phase 1: no proposals anywhere (ngram misses on every lane) ->
        # every decode-phase step takes the plain decode shape
        real_draft = eng.scheduler.draft_fn
        eng.scheduler.draft_fn = lambda req, cap: np.zeros((0,), np.int32)
        for p in prompts[:2]:
            eng.submit(p, max_new_tokens=6)
        eng.run()
        assert eng.trace_counts == {"prefill_chunk": 1, "decode": 1,
                                    "verify": 0}
        # phase 2: proposals return, with mixed prompt lengths, draft
        # lengths 0..draft_len, and partial/full acceptance — verify traces
        # once and nothing else retraces
        eng.scheduler.draft_fn = real_draft
        for i, p in enumerate(prompts):
            eng.submit(p, max_new_tokens=3 + 5 * (i % 3))
        eng.run()
        assert eng.trace_counts == {"prefill_chunk": 1, "decode": 1,
                                    "verify": 1}

    def test_draft_model_path(self, setups):
        cfg, params = setups["qwen1.5-0.5b"]
        prompts = _spec_prompts(cfg)
        on, r_on = _run(cfg, params, speculation=True, prompts=prompts,
                        draft_source="model", draft_model=(cfg, params))
        _, r_off = _run(cfg, params, speculation=False, prompts=prompts)
        assert r_on == r_off
        assert on._draft_params is not None   # really took the model path
        assert sum(m["drafted_tokens"] for m in on.metrics) > 0


class TestRollbackSafety:
    @pytest.mark.parametrize("arch", ("gemma3-12b", "deepseek-v2-lite-16b"))
    def test_garbage_drafts_never_corrupt_state(self, setups, arch):
        """Force adversarial drafts (near-certain full rejection every
        step): the emitted stream must stay identical to spec-off and the
        rollback must leave tables/refcounts/pool exactly consistent —
        including prefix-cache shared (COW) blocks below decode_pos."""
        cfg, params = setups[arch]
        prompts = _spec_prompts(cfg)
        serve = ServeConfig(slots=2, max_len=128, speculation=True,
                            draft_len=4, prefix_cache=True)
        eng = ServingEngine(cfg, params, serve)

        def garbage(req, cap):
            return (np.arange(cap, dtype=np.int32) * 7 + 3) % cfg.vocab_size

        eng.scheduler.draft_fn = garbage
        for p in prompts:
            eng.submit(p, max_new_tokens=24)
        r_on = eng.run()
        _, r_off = _run(cfg, params, speculation=False, prompts=prompts,
                        prefix_cache=True)
        assert r_on == r_off
        eng.kv.check_invariants(eng.prefix.held_blocks())
        assert sum(m["drafted_tokens"] for m in eng.metrics) > 0


# --------------------------------------------------------------- metrics
class TestMetricsSchema:
    def test_paged_metrics_carry_speculation_fields(self, setups):
        cfg, params = setups["qwen1.5-0.5b"]
        eng, _ = _run(cfg, params, speculation=True,
                      prompts=_spec_prompts(cfg))
        for m in eng.metrics:
            for k in ("verify_tokens", "drafted_tokens", "accepted_tokens",
                      "acceptance_rate"):
                assert k in m
        assert 0.0 <= eng.acceptance_rate() <= 1.0

    def test_dense_engine_schema_parity(self, setups):
        cfg, params = setups["qwen1.5-0.5b"]
        eng = DenseServingEngine(cfg, params, ServeConfig(slots=2,
                                                          max_len=64))
        for p in _spec_prompts(cfg):
            eng.submit(p, max_new_tokens=4)
        eng.run()
        assert eng.metrics
        for m in eng.metrics:
            assert m["drafted_tokens"] == 0 and m["accepted_tokens"] == 0
            assert m["verify_tokens"] == 0 and m["acceptance_rate"] == 0.0
