"""Pallas gpp_matmul (3-D tiled grid) vs pure-jnp oracle: shape/dtype sweeps,
fused-epilogue parity, ragged edges, and chunk-schedule properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

pytestmark = pytest.mark.tier1

from repro.core.schedule import plan_matmul_tiles
from repro.kernels.gpp_matmul import (
    _chunk_bounds, chunk_issue_schedule, gpp_matmul,
)
from repro.kernels.ops import (
    dense, plan_ring_depth, streamed_gemm_sequence, streamed_matmul,
)
from repro.kernels.ref import dense_ref, matmul_ref, streamed_gemm_seq_ref

jax.config.update("jax_enable_x64", False)


def rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


SHAPES = [
    (8, 256, 1024, 256),    # deep-ring regime (small M)
    (16, 512, 512, 128),
    (32, 128, 768, 256),
    (128, 256, 512, 512),   # single wide tile
    (8, 384, 1024, 128),    # K not divisible by chunks (remainder path)
]

# (M, K, N, block_m, block_n, block_k): every grid dim > 1, plus ragged edges
TILED_SHAPES = [
    (40, 300, 520, 16, 128, 128),   # ragged M, K and N
    (64, 512, 512, 32, 128, 128),   # clean 2x4x4 grid
    (16, 640, 384, 16, 128, 256),   # ragged K tile (640 = 2.5 * 256)
    (24, 128, 300, 8, 256, 128),    # ragged N < block_n on last tile
]


class TestNumerics:
    @pytest.mark.parametrize("M,K,N,bn", SHAPES)
    @pytest.mark.parametrize("G", [1, 2, 4])
    def test_matches_oracle_f32(self, M, K, N, bn, G):
        k1, k2 = jax.random.split(jax.random.PRNGKey(M * N + G))
        x, w = rand(k1, (M, K), jnp.float32), rand(k2, (K, N), jnp.float32)
        y = gpp_matmul(x, w, block_n=bn, num_bufs=G, interpret=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(matmul_ref(x, w)),
                                   rtol=1e-5, atol=1e-4)

    @pytest.mark.parametrize("M,K,N,bm,bn,bk", TILED_SHAPES)
    @pytest.mark.parametrize("G", [1, 2, 4])
    def test_3d_grid_matches_oracle(self, M, K, N, bm, bn, bk, G):
        """Parity on the full 3-D (m, n, k) grid incl. ragged final tiles."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(M + K + N + G))
        x, w = rand(k1, (M, K), jnp.float32), rand(k2, (K, N), jnp.float32)
        y = gpp_matmul(x, w, block_m=bm, block_n=bn, block_k=bk,
                       num_bufs=G, interpret=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(matmul_ref(x, w)),
                                   rtol=1e-5, atol=1e-4)

    @pytest.mark.parametrize("dtype,rtol,atol", [
        (jnp.bfloat16, 3e-2, 0.5), (jnp.float32, 1e-5, 1e-4),
    ])
    @pytest.mark.parametrize("G", [1, 2, 4])
    def test_dtype_streaming(self, dtype, rtol, atol, G):
        """bf16/f32 weights DMA'd raw, accumulated in f32, across ring depths
        and a ragged multi-tile K."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(7))
        x, w = rand(k1, (16, 320), dtype), rand(k2, (320, 512), dtype)
        y = gpp_matmul(x, w, block_m=16, block_n=128, block_k=128,
                       num_bufs=G, interpret=True)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(matmul_ref(x, w), np.float32),
                                   rtol=rtol, atol=atol)

    @pytest.mark.parametrize("G", [1, 2, 4])
    def test_int8_weight_streaming(self, G):
        """int8 weights stream raw through the ring and dequantize in-kernel
        against the f32 accumulator via the per-column epilogue scale."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(11))
        x = rand(k1, (16, 320), jnp.float32)
        w = jax.random.randint(k2, (320, 520), -127, 127, jnp.int8)
        scale = jnp.abs(rand(k2, (520,), jnp.float32)) * 0.02 + 1e-3
        y = gpp_matmul(x, w, w_scale=scale, block_m=16, block_n=128,
                       block_k=128, num_bufs=G, interpret=True)
        np.testing.assert_allclose(
            np.asarray(y),
            np.asarray(dense_ref(x, w, w_scale=scale)),
            rtol=1e-5, atol=1e-3)

    @pytest.mark.parametrize("act", [None, "relu", "gelu", "silu"])
    def test_fused_epilogue_bias_activation(self, act):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(13), 3)
        x, w = rand(k1, (24, 256), jnp.float32), rand(k2, (256, 384), jnp.float32)
        b = rand(k3, (384,), jnp.float32)
        y = gpp_matmul(x, w, bias=b, activation=act, block_m=8, block_n=128,
                       block_k=128, num_bufs=3, interpret=True)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(dense_ref(x, w, bias=b, activation=act)),
            rtol=1e-5, atol=1e-4)

    @given(st.integers(1, 6), st.integers(1, 8))
    @settings(max_examples=12, deadline=None)
    def test_strategy_invariance(self, G, seed):
        """All ring depths compute the same function (schedule is semantics-free)."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        x, w = rand(k1, (8, 128), jnp.float32), rand(k2, (128, 512), jnp.float32)
        y = gpp_matmul(x, w, block_n=128, num_bufs=G, interpret=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(matmul_ref(x, w)),
                                   rtol=1e-5, atol=1e-4)

    def test_sequence_matches_oracle(self):
        k1, k2 = jax.random.split(jax.random.PRNGKey(3))
        x = rand(k1, (8, 256), jnp.float32)
        ws = rand(k2, (5, 256, 512), jnp.float32)
        ys = streamed_gemm_sequence(x, ws, block_n=128, num_bufs=4, interpret=True)
        np.testing.assert_allclose(np.asarray(ys),
                                   np.asarray(streamed_gemm_seq_ref(x, ws)),
                                   rtol=1e-5, atol=1e-4)

    def test_ragged_n_no_longer_errors(self):
        """N % block_n != 0 pads the last ragged tile instead of raising."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(5))
        x, w = rand(k1, (8, 128), jnp.float32), rand(k2, (128, 300), jnp.float32)
        y = gpp_matmul(x, w, block_n=256, num_bufs=2, interpret=True)
        assert y.shape == (8, 300)
        np.testing.assert_allclose(np.asarray(y), np.asarray(matmul_ref(x, w)),
                                   rtol=1e-5, atol=1e-4)

    def test_tiny_k_no_longer_errors(self):
        """K < chunks clamps the chunk count instead of raising."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(6))
        x, w = rand(k1, (8, 2), jnp.float32), rand(k2, (2, 256), jnp.float32)
        y = gpp_matmul(x, w, block_n=128, num_bufs=8, interpret=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(matmul_ref(x, w)),
                                   rtol=1e-5, atol=1e-4)

    def test_vmem_exceeding_shape_now_tiles(self):
        """A shape whose naive working set busts the old ~100 MiB ceiling
        (whole-K ring + whole-M activations resident) runs via M/K tiling."""
        M, K, N = 256, 8192, 2048
        # the old 1-D kernel's configuration: whole K and M resident
        with pytest.raises(ValueError, match="VMEM"):
            plan_matmul_tiles(M, K, N, block_m=M, block_k=K, block_n=2048,
                              num_bufs=4)
        k1, k2 = jax.random.split(jax.random.PRNGKey(9))
        x, w = rand(k1, (M, K), jnp.float32), rand(k2, (K, N), jnp.float32)
        y = gpp_matmul(x, w, interpret=True)  # auto-planned tiles
        np.testing.assert_allclose(np.asarray(y), np.asarray(matmul_ref(x, w)),
                                   rtol=1e-5, atol=2e-3)

    def test_dense_kernel_path_is_differentiable(self):
        """Training goes through dense(mode=auto->kernel) on TPU: the kernel
        path carries a custom_vjp (ref-math backward), so grads must exist
        and match the ref route."""
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(19), 3)
        x = rand(k1, (4, 128), jnp.float32)
        w = rand(k2, (128, 256), jnp.float32) * 0.05
        b = rand(k3, (256,), jnp.float32) * 0.1

        def loss(mode):
            def f(x, w, b):
                y = dense(x, w, bias=b, activation="silu", mode=mode)
                return jnp.sum(y * y)
            return f

        gk = jax.grad(loss("interpret"), argnums=(0, 1, 2))(x, w, b)
        gr = jax.grad(loss("ref"), argnums=(0, 1, 2))(x, w, b)
        for a, r in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(r),
                                       rtol=1e-4, atol=1e-4)

    def test_dense_routes_and_matches(self):
        """dense() ref/interpret routes agree on leading-dim inputs."""
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(17), 3)
        x = rand(k1, (2, 6, 256), jnp.float32)
        w = rand(k2, (256, 384), jnp.float32)
        b = rand(k3, (384,), jnp.float32)
        y_ref = dense(x, w, bias=b, activation="silu", mode="ref")
        y_krn = dense(x, w, bias=b, activation="silu", mode="interpret")
        assert y_ref.shape == y_krn.shape == (2, 6, 384)
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_krn),
                                   rtol=1e-5, atol=1e-4)

    @pytest.mark.parametrize("scale_shape", ["scalar", "per_expert", "per_col"])
    def test_grouped_int8_dequant_matches_flat_epilogue(self, scale_shape):
        """int8 `w_scale` dequant for the grouped kernel: per-expert scales
        fold into the fused epilogue and reproduce the FLAT kernel's dequant
        path expert-by-expert (plus the grouped oracle)."""
        from repro.kernels.gpp_matmul import gpp_matmul_grouped
        from repro.kernels.ref import dense_grouped_ref
        E, C, D, F = 3, 13, 64, 96
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(23), 3)
        x = rand(k1, (E, C, D), jnp.float32)
        w = jax.random.randint(k2, (E, D, F), -127, 127, jnp.int8)
        full = jnp.abs(rand(k3, (E, F), jnp.float32)) * 0.02 + 1e-3
        scale = {"scalar": full[0, 0], "per_expert": full[:, 0],
                 "per_col": full}[scale_shape]
        y = gpp_matmul_grouped(x, w, w_scale=scale, activation="silu",
                               interpret=True)
        np.testing.assert_allclose(
            np.asarray(y),
            np.asarray(dense_grouped_ref(x, w, w_scale=scale,
                                         activation="silu")),
            rtol=1e-5, atol=1e-3)
        if scale_shape == "per_col":
            for e in range(E):
                flat = gpp_matmul(x[e], w[e], w_scale=scale[e],
                                  activation="silu", interpret=True)
                np.testing.assert_allclose(np.asarray(y[e]), np.asarray(flat),
                                           rtol=1e-5, atol=1e-3)

    def test_grouped_dequant_ref_mode_and_grads(self):
        """dense_grouped(mode="ref") pre-scales like dense()'s ref path, and
        the kernel path stays differentiable with a scale attached."""
        from repro.kernels.ops import dense_grouped
        from repro.kernels.ref import dense_grouped_ref
        E, C, D, F = 2, 8, 32, 48
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(29), 3)
        x = rand(k1, (E, C, D), jnp.float32)
        w = rand(k2, (E, D, F), jnp.float32) * 0.05
        scale = jnp.abs(rand(k3, (E, F), jnp.float32)) + 0.5
        y_ref = dense_grouped(x, w, w_scale=scale, mode="ref")
        y_krn = dense_grouped(x, w, w_scale=scale, mode="interpret")
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_krn),
                                   rtol=1e-4, atol=1e-4)

        def loss(mode):
            def f(x, w):
                y = dense_grouped(x, w, w_scale=scale, activation="silu",
                                  mode=mode)
                return jnp.sum(y * y)
            return f

        gk = jax.grad(loss("interpret"), argnums=(0, 1))(x, w)
        gr = jax.grad(loss("ref"), argnums=(0, 1))(x, w)
        for a, r in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(r),
                                       rtol=1e-4, atol=1e-4)


class TestPlanner:
    def test_respects_budget(self):
        from repro.core.schedule import matmul_vmem_bytes
        plan = plan_matmul_tiles(4096, 16384, 32768, x_itemsize=2,
                                 w_itemsize=2, out_itemsize=2)
        assert plan.vmem_bytes <= 100 * 1024 * 1024
        assert plan.vmem_bytes == matmul_vmem_bytes(
            plan.block_m, plan.block_n, plan.block_k, plan.num_bufs,
            x_itemsize=2, w_itemsize=2, out_itemsize=2)

    def test_pinned_dims_honored(self):
        plan = plan_matmul_tiles(512, 4096, 4096, block_n=512, num_bufs=3)
        assert plan.block_n == 512 and plan.num_bufs == 3

    def test_small_shapes_single_tile(self):
        plan = plan_matmul_tiles(8, 256, 256)
        assert plan.block_m >= 8 and plan.block_k >= 256 and plan.block_n >= 256
        assert plan.grid(8, 256, 256) == (1, 1, 1)

    def test_pinned_overflow_raises(self):
        with pytest.raises(ValueError, match="VMEM"):
            plan_matmul_tiles(8192, 8192, 8192, block_m=8192, block_k=8192,
                              block_n=8192, num_bufs=2)

    def test_planner_regimes(self):
        """Paper's insight in kernel form: DMA-bound (small n_in=M) needs a
        deep ring; compute-bound (large M) degenerates to double buffering."""
        assert plan_ring_depth(8, 256, 256) >= 4
        assert plan_ring_depth(1024, 256, 256) == 2


class TestSchedule:
    def test_chunk_schedule_covers_every_chunk_once(self):
        """Replay the kernel's issue schedule symbolically on the flattened
        3-D grid: every (step, chunk) must be DMA'd exactly once, at or
        before the step that computes on it — including across n/k/m
        tile-loop boundaries and short (sub-ramp) grids."""
        for G in (1, 2, 3, 4, 6):
            C = max(1, G - 1)
            for grid in [(1, 1, 1), (1, 2, 3), (2, 3, 2), (1, G, 1),
                         (3, 1, 1), (2, 2, G + 2)]:
                S = grid[0] * grid[1] * grid[2]
                issued = chunk_issue_schedule(S, G, C)
                for s in range(S):
                    for c in range(C):
                        steps = issued.get((s, c), [])
                        assert len(steps) == 1, (G, grid, s, c, steps)
                        assert steps[0] <= s, "chunk must arrive before compute"
                extra = set(issued) - {(s, c) for s in range(S) for c in range(C)}
                assert not extra, (G, grid, extra)

    def test_chunk_bounds_partition(self):
        for K in (128, 384, 1000):
            for chunks in (1, 2, 3, 5, 7):
                if K < chunks:
                    continue
                spans = [_chunk_bounds(K, chunks, c) for c in range(chunks)]
                assert spans[0][0] == 0 and spans[-1][1] == K
                for (a, b), (c, d) in zip(spans, spans[1:]):
                    assert b == c

    def test_flat_bandwidth_bytes_per_step(self):
        """Steady-state issued bytes per grid step == exactly one tile, even
        across the n->n+1 and m->m+1 tile-loop boundaries."""
        G, bk, bn = 4, 384, 128
        C = G - 1
        grid = (2, 3, 2)                     # (num_m, num_n, num_k)
        S = grid[0] * grid[1] * grid[2]
        issued = chunk_issue_schedule(S, G, C)
        per_step = [0] * S
        for (step, c), at in issued.items():
            lo, hi = _chunk_bounds(bk, C, c)
            per_step[at[0]] += (hi - lo) * bn
        tile = bk * bn
        # steady-state steps (past ramp, before drain) move exactly one tile
        for j in range(1, S - C):
            assert per_step[j] == tile, (j, per_step[j], tile)
        # the ramp step must burst (pipeline fill), the drain steps taper
        assert per_step[0] > tile
        assert sum(per_step) == S * tile
