"""Pallas gpp_matmul vs pure-jnp oracle: shape/dtype sweeps + schedule props."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.gpp_matmul import _chunk_bounds, gpp_matmul
from repro.kernels.ops import plan_ring_depth, streamed_gemm_sequence, streamed_matmul
from repro.kernels.ref import matmul_ref, streamed_gemm_seq_ref

jax.config.update("jax_enable_x64", False)


def rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


SHAPES = [
    (8, 256, 1024, 256),    # deep-ring regime (small M)
    (16, 512, 512, 128),
    (32, 128, 768, 256),
    (128, 256, 512, 512),   # single wide tile
    (8, 384, 1024, 128),    # K not divisible by chunks (remainder path)
]


class TestNumerics:
    @pytest.mark.parametrize("M,K,N,bn", SHAPES)
    @pytest.mark.parametrize("G", [1, 2, 4])
    def test_matches_oracle_f32(self, M, K, N, bn, G):
        k1, k2 = jax.random.split(jax.random.PRNGKey(M * N + G))
        x, w = rand(k1, (M, K), jnp.float32), rand(k2, (K, N), jnp.float32)
        y = gpp_matmul(x, w, block_n=bn, num_bufs=G, interpret=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(matmul_ref(x, w)),
                                   rtol=1e-5, atol=1e-4)

    @pytest.mark.parametrize("dtype,rtol,atol", [
        (jnp.bfloat16, 3e-2, 0.5), (jnp.float32, 1e-5, 1e-4),
    ])
    def test_dtypes(self, dtype, rtol, atol):
        k1, k2 = jax.random.split(jax.random.PRNGKey(7))
        x, w = rand(k1, (16, 256), dtype), rand(k2, (256, 512), dtype)
        y = gpp_matmul(x, w, block_n=128, num_bufs=4, interpret=True)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(matmul_ref(x, w), np.float32),
                                   rtol=rtol, atol=atol)

    @given(st.integers(1, 6), st.integers(1, 8))
    @settings(max_examples=12, deadline=None)
    def test_strategy_invariance(self, G, seed):
        """All ring depths compute the same function (schedule is semantics-free)."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        x, w = rand(k1, (8, 128), jnp.float32), rand(k2, (128, 512), jnp.float32)
        y = gpp_matmul(x, w, block_n=128, num_bufs=G, interpret=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(matmul_ref(x, w)),
                                   rtol=1e-5, atol=1e-4)

    def test_sequence_matches_oracle(self):
        k1, k2 = jax.random.split(jax.random.PRNGKey(3))
        x = rand(k1, (8, 256), jnp.float32)
        ws = rand(k2, (5, 256, 512), jnp.float32)
        ys = streamed_gemm_sequence(x, ws, block_n=128, num_bufs=4, interpret=True)
        np.testing.assert_allclose(np.asarray(ys),
                                   np.asarray(streamed_gemm_seq_ref(x, ws)),
                                   rtol=1e-5, atol=1e-4)

    def test_error_on_misaligned(self):
        x = jnp.zeros((8, 128)); w = jnp.zeros((128, 300))
        with pytest.raises(ValueError):
            gpp_matmul(x, w, block_n=256, num_bufs=2, interpret=True)

    def test_error_on_vmem_overflow(self):
        x = jnp.zeros((8, 8192), jnp.float32)
        w = jnp.zeros((8192, 16384), jnp.float32)
        with pytest.raises(ValueError, match="VMEM"):
            gpp_matmul(x, w, block_n=8192, num_bufs=8, interpret=True)


class TestSchedule:
    def test_chunk_schedule_covers_every_chunk_once(self):
        """Replay the kernel's issue schedule symbolically: every (tile, chunk)
        must be issued exactly once, and before the tile's compute step."""
        for G in (2, 3, 4, 6):
            C = G - 1
            for nt in (1, 2, G - 1, G, G + 3, 4 * G):
                issued = {}
                for j in range(nt):
                    if j == 0:
                        for c in range(C):
                            issued.setdefault((0, c), []).append(j)
                        for k in range(1, G - 1):
                            if k < nt:
                                for c in range(0, C - k):
                                    issued.setdefault((k, c), []).append(j)
                    for k in range(1, G):
                        c = C - k
                        if c >= 0 and j + k < nt:
                            issued.setdefault((j + k, c), []).append(j)
                for t in range(nt):
                    for c in range(C):
                        steps = issued.get((t, c), [])
                        assert len(steps) == 1, (G, nt, t, c, steps)
                        assert steps[0] <= t, "chunk must arrive before compute"

    def test_chunk_bounds_partition(self):
        for K in (128, 384, 1000):
            for chunks in (1, 2, 3, 5, 7):
                if K < chunks:
                    continue
                spans = [_chunk_bounds(K, chunks, c) for c in range(chunks)]
                assert spans[0][0] == 0 and spans[-1][1] == K
                for (a, b), (c, d) in zip(spans, spans[1:]):
                    assert b == c

    def test_planner_regimes(self):
        """Paper's insight in kernel form: DMA-bound (small n_in=M) needs a
        deep ring; compute-bound (large M) degenerates to double buffering."""
        assert plan_ring_depth(8, 256, 256) >= 4
        assert plan_ring_depth(1024, 256, 256) == 2

    def test_flat_bandwidth_bytes_per_step(self):
        """Steady-state issued bytes per grid step == exactly one tile."""
        G, nt, K, bn = 4, 12, 384, 128
        C = G - 1
        per_step = [0] * nt
        for j in range(nt):
            if j == 0:
                for c in range(C):
                    lo, hi = _chunk_bounds(K, C, c)
                    per_step[j] += (hi - lo) * bn
                for k in range(1, G - 1):
                    for c in range(0, C - k):
                        lo, hi = _chunk_bounds(K, C, c)
                        per_step[j] += (hi - lo) * bn
            for k in range(1, G):
                c = C - k
                if c >= 0 and j + k < nt:
                    lo, hi = _chunk_bounds(K, C, c)
                    per_step[j] += (hi - lo) * bn
        tile = K * bn
        # steady-state steps (past ramp, before drain) move exactly one tile
        for j in range(1, nt - G + 1):
            assert per_step[j] == tile, (j, per_step[j], tile)
        # naive double-buffering reference: same average, but the ramp step
        # must burst (G-1 tiles worth at step 0 here)
        assert per_step[0] > tile
