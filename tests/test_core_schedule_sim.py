"""Tests for the schedule IR builders and the cycle-accurate simulator."""
import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import analytical as ana
from repro.core import dse
from repro.core import runtime_adapt
from repro.core import schedule as sched
from repro.core import simulator as dessim
from repro.core.analytical import PimConfig


def cfg_with_ratio(ratio_pim_over_rw: float, band: float = 1e9) -> PimConfig:
    """Config with t_pim/t_rw == ratio (band large => no arbiter contention)."""
    c = PimConfig(band=band)
    return c.with_(n_in=ratio_pim_over_rw * c.size_ou / c.s)


class TestScheduleBuilders:
    def test_gpp_flat_bandwidth_steady_state(self):
        """The core claim: GPP's off-chip demand is flat (peak == average) in
        steady state for divisible group sizes."""
        c = cfg_with_ratio(3.0)  # t_pim : t_rw = 3 : 1 -> 4 groups
        s = sched.build("gpp", c, num_macros=8, rounds=6)
        # steady state: ignore first+last period
        period = c.time_pim + c.time_rewrite
        prof = [
            op for op in s.ops
            if op.kind == "rewrite" and period <= op.start and op.end <= s.makespan - period
        ]
        # at any instant exactly 2 of 8 macros rewrite (8 * 1/4)
        events = sorted([(op.start, +1) for op in prof] + [(op.end, -1) for op in prof])
        cur, seen = 0, set()
        for t, d in events:
            cur += d
            seen.add(cur)
        assert max(seen) == 2

    def test_gpp_zero_macro_idle(self):
        c = cfg_with_ratio(3.0)
        s = sched.build("gpp", c, num_macros=8, rounds=8)
        # each macro: busy rounds*(tp+tr) out of makespan - its own stagger tail
        period = c.time_pim + c.time_rewrite
        per_macro_busy = 8 * period
        # macro_utilization over the whole makespan includes ramp; the busy
        # time per macro must be exactly rounds*period (no inserted idle).
        for m in range(8):
            busy = sum(op.dur for op in s.ops if op.macro == m)
            assert busy == pytest.approx(per_macro_busy)

    def test_insitu_bandwidth_bursty(self):
        c = cfg_with_ratio(3.0)
        s = sched.build("insitu", c, 8, 4)
        assert s.bandwidth_idle_fraction() == pytest.approx(0.75, abs=0.01)
        assert s.peak_bandwidth() == pytest.approx(8 * c.s)

    def test_gpp_peak_bandwidth_quarter_of_insitu(self):
        """Paper Fig 3: with ratio 1:3, GPP peak BW = 25% of in-situ's."""
        c = cfg_with_ratio(3.0)
        si = sched.build("insitu", c, 8, 4)
        sg = sched.build("gpp", c, 8, 4)
        assert sg.peak_bandwidth() / si.peak_bandwidth() == pytest.approx(0.25)

    @given(st.integers(2, 24), st.floats(0.5, 12), st.integers(1, 6))
    @settings(max_examples=30, deadline=None)
    def test_builders_make_valid_schedules(self, n_macros, ratio, rounds):
        c = cfg_with_ratio(ratio)
        for strat in ana.STRATEGIES:
            s = sched.build(strat, c, n_macros, rounds)
            # no macro overlaps itself
            by_macro = {}
            for op in s.ops:
                by_macro.setdefault(op.macro, []).append(op)
            for ops in by_macro.values():
                ops.sort(key=lambda o: o.start)
                for a, b in zip(ops, ops[1:]):
                    assert a.end <= b.start + 1e-6
            # every compute is preceded by a rewrite of the same macro
            for m, ops in by_macro.items():
                kinds = [o.kind for o in sorted(ops, key=lambda o: o.start)]
                for i, k in enumerate(kinds):
                    if k == "compute":
                        assert "rewrite" in kinds[:i]


class TestSimulator:
    def test_gpp_matches_schedule_when_uncontended(self):
        """With band >= demand the DES must realize the ideal schedule length."""
        c = cfg_with_ratio(3.0, band=1e9)
        res = dessim.simulate("gpp", c, 8, 4)
        s = sched.build("gpp", c, 8, 4)
        assert res.total_cycles == pytest.approx(s.makespan, rel=1e-6)

    def test_insitu_closed_form(self):
        c = cfg_with_ratio(2.0, band=64.0)
        res = dessim.simulate("insitu", c, 8, 5)
        rate = min(c.s, c.band / 8)
        expect = 5 * (c.size_macro / rate + c.time_pim)
        assert res.total_cycles == pytest.approx(expect)

    def test_naive_pp_period_max(self):
        """naive pp steady period is max(t_pim, t_rw) (paper Fig 3b)."""
        c = cfg_with_ratio(4.0, band=1e9)
        res = dessim.simulate("naive_pp", c, 8, 8)
        # 2*rounds phases of max(tp,tr) (+ warmup tr)
        expect = c.time_rewrite + 2 * 8 * max(c.time_pim, c.time_rewrite)
        assert res.total_cycles == pytest.approx(expect, rel=0.01)

    def test_gpp_beats_naive_when_mismatched(self):
        c = cfg_with_ratio(7.0, band=128.0)  # t_rw : t_pim = 1:7
        n_g = max(1, round(ana.num_macros(c, "gpp")))
        n_n = max(1, round(ana.num_macros(c, "naive_pp")))
        work = 32 * n_g
        g = dessim.simulate("gpp", c, n_g, math.ceil(work / n_g))
        n = dessim.simulate("naive_pp", c, n_n, math.ceil(work / n_n))
        # per-unit-work latency
        lat_g = g.total_cycles / (n_g * g.rounds)
        lat_n = n.total_cycles / (n_n * n.rounds)
        assert lat_n / lat_g > 1.67  # paper: "over 1.67x" headline

    def test_gpp_full_bandwidth_utilization(self):
        """At the Eq-4 design point GPP keeps the bus busy ~100% of the time."""
        c = cfg_with_ratio(3.0, band=32.0)
        n = max(1, round(ana.num_macros(c, "gpp")))
        res = dessim.simulate("gpp", c, n, 16)
        assert res.bandwidth_utilization > 0.95

    def test_conservation_of_bytes(self):
        c = cfg_with_ratio(2.5, band=48.0)
        res = dessim.simulate("gpp", c, 6, 7)
        assert res.bytes_transferred == pytest.approx(6 * 7 * c.size_macro, rel=1e-6)

    @given(st.sampled_from(["insitu", "naive_pp", "gpp"]),
           st.integers(2, 16), st.floats(0.5, 8), st.integers(1, 5),
           st.floats(8, 512))
    @settings(max_examples=40, deadline=None)
    def test_invariants(self, strat, n_macros, ratio, rounds, band):
        c = cfg_with_ratio(ratio, band=band)
        res = dessim.simulate(strat, c, n_macros, rounds)
        assert res.total_cycles > 0
        assert res.bytes_transferred == pytest.approx(
            n_macros * rounds * c.size_macro, rel=1e-5
        )
        assert res.peak_bandwidth <= min(band, n_macros * c.s) + 1e-6
        assert 0.0 < res.macro_utilization <= 1.0 + 1e-9
        # compute cycles are exact: every macro computes rounds * t_pim
        assert res.compute_cycles == pytest.approx(n_macros * rounds * c.time_pim, rel=1e-6)

    @given(st.integers(2, 12), st.floats(0.5, 6), st.floats(16, 256))
    @settings(max_examples=25, deadline=None)
    def test_gpp_no_slower_than_insitu_steady_state(self, n_macros, ratio, band):
        """GPP's steady-state round latency never exceeds in-situ's; its only
        overhead is the one-period stagger ramp (pipeline fill)."""
        c = cfg_with_ratio(ratio, band=band)
        g = dessim.simulate("gpp", c, n_macros, 8)
        i = dessim.simulate("insitu", c, n_macros, 8)
        ramp = c.time_pim + c.time_rewrite
        assert g.total_cycles <= i.total_cycles + ramp * 1.001


class TestTable2:
    PAPER = {
        256: (82.05, 1.56, 0.7808), 128: (54.01, 2.37, 0.5931),
        64: (36.26, 3.53, 0.4414), 32: (24.71, 5.18, 0.3237),
        16: (17.02, 7.52, 0.2349), 8: (11.83, 10.82, 0.1691),
    }

    def test_theory_matches_paper(self):
        for row in dse.table2():
            m, r, p = self.PAPER[int(row.band)]
            assert row.macros_theory == pytest.approx(m, rel=2e-3)
            assert row.ratio_theory == pytest.approx(r, abs=0.01)
            assert row.perf_theory == pytest.approx(p, abs=1e-3)

    def test_practice_integer_feasible(self):
        for row in dse.table2():
            assert row.macros_practice == int(row.macros_practice)
            assert row.macros_practice <= row.macros_theory + 1e-9
            # integer point can't beat the fractional optimum
            assert row.perf_practice <= row.perf_theory + 1e-9
            # ... and our optimizer is at least as good as the paper's build
            paper_practice = {256: 0.75, 128: 0.5469, 64: 0.4375,
                              32: 0.3125, 16: 0.2188, 8: 0.1563}
            assert row.perf_practice >= paper_practice[int(row.band)] - 1e-4


class TestRuntimeAdaptation:
    def test_fig7_ordering_and_headline(self):
        pts = runtime_adapt.fig7_sweep(rounds=32)
        by = {(p.strategy, p.band_reduction): p for p in pts}
        for n in (2.0, 8.0, 64.0):
            g, i, na = by[("gpp", n)], by[("insitu", n)], by[("naive_pp", n)]
            assert g.perf_sim >= i.perf_sim - 1e-6
            assert i.perf_sim >= na.perf_sim - 1e-6
        # paper: 5.38x over in-situ at band/64
        g, i = by[("gpp", 64.0)], by[("insitu", 64.0)]
        assert g.perf_sim / i.perf_sim == pytest.approx(5.38, abs=0.35)

    def test_gpp_bw_utilization_stays_high(self):
        """Fig 7c: GPP keeps the (reduced) bus nearly saturated at every
        reduction; integer macro rounding can leave a little slack."""
        pts = runtime_adapt.fig7_sweep(rounds=32)
        for p in pts:
            if p.strategy == "gpp":
                assert p.bw_utilization > 0.8
        # and on average it is very close to full
        gpps = [p.bw_utilization for p in pts if p.strategy == "gpp"]
        assert sum(gpps) / len(gpps) > 0.92
