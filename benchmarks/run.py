"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (derived = the headline quantity the
paper reports for that table/figure) and mirrors every row into
``BENCH_kernels.json`` (name -> {us_per_call, derived}) so the perf
trajectory is machine-readable across PRs.  Serving benchmarks append into
``BENCH_serving.json`` (same append-don't-rename contract).
Run:  PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import json
import math
import os
import time

RESULTS: "dict[str, dict]" = {}
SERVING_RESULTS: "dict[str, dict]" = {}

# anchored to the repo root (not the CWD) so the tracked perf record and
# TimingCache.from_bench_json consumers always see the same file
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(_ROOT, "BENCH_kernels.json")
BENCH_SERVING_JSON = os.path.join(_ROOT, "BENCH_serving.json")


def _record_serving(name: str, us: float, derived: str,
                    extra: dict | None = None) -> None:
    entry = {"us_per_call": round(us, 1), "derived": derived}
    if extra:
        entry.update(extra)
    SERVING_RESULTS[name] = entry
    print(f"{name},{us:.1f},{derived}")


def _append_json(path: str, entries: "dict[str, dict]") -> None:
    """Merge `entries` into the JSON record at `path` (append, don't rename:
    existing keys from earlier PRs survive unless overwritten by name)."""
    record: dict = {}
    if os.path.exists(path):
        with open(path) as f:
            record = json.load(f)
    record.update(entries)
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path} ({len(entries)} updated / {len(record)} total)")


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return (time.perf_counter() - t0) * 1e6, out


def _record(name: str, us: float, derived: str, extra: dict | None = None) -> None:
    entry = {"us_per_call": round(us, 1), "derived": derived}
    if extra:
        entry.update(extra)
    RESULTS[name] = entry
    print(f"{name},{us:.1f},{derived}")


def bench_fig4_naive_pp_utilization():
    """Fig 4: naive ping-pong macro utilization vs n_in (peaks at n_in=8)."""
    from repro.core.analytical import PimConfig, naive_pp_macro_util

    def run():
        cfg = PimConfig()  # paper setup: 32x32 B macro, OU 4x8 B, s=4
        curve = {n: naive_pp_macro_util(cfg.with_(n_in=n))
                 for n in (1, 2, 4, 8, 16, 32, 64)}
        assert abs(curve[8] - 1.0) < 1e-9
        return curve

    us, curve = _timed(run)
    _record("fig4_naive_pp_utilization", us, f"peak@n_in=8:util={curve[8]:.3f}")


def bench_fig6_design_phase():
    """Fig 6: execution time + macro count of the three strategies across
    t_rw:t_pim ratios at fixed off-chip bandwidth (DES-backed)."""
    from repro.core.analytical import PimConfig
    from repro.core.dse import fig6_sweep

    def run():
        cfg = PimConfig(band=128.0, s=4.0)
        pts = fig6_sweep(cfg, ratios=[1 / 7, 1 / 3, 1.0, 3.0, 8.0],
                         workload_rounds=24)
        by = {(p.strategy, round(p.ratio_rw_over_pim, 3)): p for p in pts}
        r17 = round(1 / 7, 3)
        gpp_vs_naive = (by[("naive_pp", r17)].exec_time
                        / by[("gpp", r17)].exec_time)
        gpp_vs_insitu = (by[("insitu", r17)].exec_time
                         / by[("gpp", r17)].exec_time)
        return gpp_vs_naive, gpp_vs_insitu

    us, (vs_naive, vs_insitu) = _timed(run)
    _record("fig6_design_phase", us, f"ratio1:7_gpp_speedup_vs_naive={vs_naive:.2f}x_vs_insitu={vs_insitu:.2f}x")


def bench_fig7_runtime_adaptation():
    """Fig 7: performance retention under bandwidth reduction (paper headline:
    5.38x over in-situ at band/64; our naive ratio reported alongside)."""
    from repro.core.runtime_adapt import fig7_sweep

    def run():
        pts = fig7_sweep(reductions=(1, 2, 4, 8, 16, 32, 64), rounds=32)
        by = {(p.strategy, p.band_reduction): p for p in pts}
        g, i, n = (by[("gpp", 64.0)], by[("insitu", 64.0)],
                   by[("naive_pp", 64.0)])
        return g.perf_sim / i.perf_sim, g.perf_sim / n.perf_sim, g.bw_utilization

    us, (vs_insitu, vs_naive, bwu) = _timed(run)
    _record("fig7_runtime_adaptation", us, f"band/64_gpp_vs_insitu={vs_insitu:.2f}x_vs_naive={vs_naive:.2f}x_bw_util={bwu:.2f}")


def bench_table2_theory_practice():
    """Table II: theory vs integer practice across band 8..256 B/cycle."""
    from repro.core.dse import table2

    def run():
        rows = table2()
        worst = max(abs(r.perf_theory - r.perf_practice) / r.perf_theory
                    for r in rows)
        return rows, worst

    us, (rows, worst) = _timed(run)
    r8 = next(r for r in rows if r.band == 8)
    _record("table2_theory_practice", us,
            f"band8_macros={r8.macros_practice}"
            f"_perf={r8.perf_practice:.4f}_maxgap={worst:.3f}")


def bench_headline_1_67x():
    """§V headline: GPP >= 1.67x over naive ping-pong at full BW utilization
    (checked across the mismatch range with the cycle-accurate DES)."""
    import repro.core.analytical as ana
    from repro.core import simulator as sim

    def run():
        best = 0.0
        for ratio in (3.0, 5.0, 7.0):
            c = ana.PimConfig(band=128.0, s=4.0).with_(
                n_in=ratio * 32 / 4.0)
            n_g = max(1, round(ana.num_macros(c, "gpp")))
            n_n = max(1, round(ana.num_macros(c, "naive_pp")))
            work = 32 * n_g
            g = sim.simulate("gpp", c, n_g, math.ceil(work / n_g))
            n = sim.simulate("naive_pp", c, n_n, math.ceil(work / n_n))
            lat_g = g.total_cycles / (n_g * g.rounds)
            lat_n = n.total_cycles / (n_n * n.rounds)
            best = max(best, lat_n / lat_g)
        return best

    us, best = _timed(run)
    _record("headline_full_bw", us, f"gpp_vs_naive_best={best:.2f}x_(paper:>=1.67x)")


def bench_kernel_gpp_matmul():
    """Kernel: interpret-mode correctness + auto ring-depth planning for
    in-situ (G=1) / naive (G=2) / GPP (G>=3) schedules."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels.ops import plan_ring_depth, streamed_matmul
    from repro.kernels.ref import matmul_ref

    def run():
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (8, 256), jnp.float32)
        w = jax.random.normal(key, (256, 2048), jnp.float32)
        for G in (1, 2, 4):
            y = streamed_matmul(x, w, block_n=256, num_bufs=G, interpret=True)
            np.testing.assert_allclose(np.asarray(y),
                                       np.asarray(matmul_ref(x, w)),
                                       rtol=1e-5, atol=1e-4)
        return plan_ring_depth(8, 256, 256)

    us, g_auto = _timed(run)
    _record("kernel_gpp_matmul", us, f"allclose_G=1/2/4_auto_ring={g_auto}")


def bench_kernel_cycle_model():
    """Model the gpp_matmul kernel's three schedules with the paper's own
    analytic machinery mapped to TPU constants (DESIGN.md §2.1): a (K, bn)
    weight tile is the macro, HBM bandwidth is the off-chip bus, M rows are
    n_in.  Reports the modeled steady-state speedup of GPP over in-situ for
    a DMA-bound shape (small M) — the regime the auto-planner picks G=8."""
    from repro.core.analytical import PimConfig
    from repro.core import simulator as sim
    from repro.kernels.ops import HBM_BYTES_PER_S, PEAK_FLOPS, plan_ring_depth

    def run():
        out = {}
        for M in (8, 128, 512):   # DMA-bound .. balanced .. compute-bound
            K, bn = 256, 256
            tile_bytes = K * bn * 2
            t_dma = tile_bytes / HBM_BYTES_PER_S
            t_cmp = 2 * M * K * bn / PEAK_FLOPS
            n_tiles = 16
            serial = n_tiles * (t_dma + t_cmp)                 # in-situ (G=1)
            pipelined = t_dma + t_cmp + (n_tiles - 1) * max(t_dma, t_cmp)
            out[M] = (serial / pipelined, plan_ring_depth(M, K, bn))
        return out

    us, out = _timed(run)
    parts = "_".join(f"M{m}:{s:.2f}x(G={g})" for m, (s, g) in out.items())
    _record("kernel_cycle_model", us, f"insitu_to_pipelined_{parts}")


def bench_streamer_modes():
    """Distributed streamer: all four write/compute schedules agree
    numerically (ZeRO-3 gathers restructured per the paper's schedule)."""
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core.streamer import StreamSettings, stream_layers

    def run():
        if len(jax.devices()) < 4:
            return "skipped(<4 devices)"
        from repro.launch.mesh import make_mesh_compat, mesh_context
        mesh = make_mesh_compat((2, 2), ("data", "model"))
        L, D, F, B = 4, 32, 64, 8
        key = jax.random.PRNGKey(0)
        ws = {"w1": jax.random.normal(key, (L, D, F)) * 0.05,
              "w2": jax.random.normal(key, (L, F, D)) * 0.05}
        x = jax.random.normal(key, (B, D))
        shard = {"w1": P("data", None), "w2": P(None, "data")}
        full = {"w1": P(None, None), "w2": P(None, None)}

        def apply_fn(c, w):
            return c + jnp.tanh(c @ w["w1"]) @ w["w2"]

        outs = {}
        with mesh_context(mesh):
            for mode in ("resident", "insitu", "naive_pp", "gpp"):
                f = jax.jit(lambda x, ws, m=mode: stream_layers(
                    apply_fn, x, ws, L,
                    settings=StreamSettings(mode=m, ring_depth=3),
                    mesh=mesh, shard_specs=shard, full_specs=full))
                outs[mode] = np.asarray(f(x, ws))
        for m in ("insitu", "naive_pp", "gpp"):
            np.testing.assert_allclose(outs[m], outs["resident"],
                                       rtol=1e-5, atol=1e-5)
        return "4modes_allclose"

    us, res = _timed(run)
    _record("streamer_modes", us, str(res))


def bench_kernel_tiled_vmem():
    """Tiled 3-D-grid gpp_matmul at a shape whose naive (whole-M/whole-K
    resident) working set exceeds the old 1-D kernel's ~100 MiB VMEM ceiling.

    "before" = the pre-tiling configuration: the whole (M, K) activation
    block plus a G x K x block_n weight ring resident — the planner rejects
    it, exactly as the old kernel hard-errored.  "after" = the auto-planned
    M/K-tiled kernel at the same shape, parity-checked against the oracle.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.schedule import matmul_vmem_bytes, plan_matmul_tiles
    from repro.kernels.gpp_matmul import gpp_matmul
    from repro.kernels.ref import matmul_ref

    M, K, N, bn_old, G_old = 1024, 4096, 8192, 2048, 4
    naive = matmul_vmem_bytes(M, bn_old, K, G_old,
                              x_itemsize=4, w_itemsize=4, out_itemsize=4)

    def run_before():
        try:
            plan_matmul_tiles(M, K, N, block_m=M, block_k=K, block_n=bn_old,
                              num_bufs=G_old)
        except ValueError:
            return f"raises_ValueError(naive_ws={naive / 2**20:.0f}MiB)"
        return "unexpectedly_fit"

    us, derived = _timed(run_before)
    _record("kernel_tiled_vmem_before", us, derived)

    def run_after():
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        x = jax.random.normal(k1, (M, K), jnp.float32)
        w = jax.random.normal(k2, (K, N), jnp.float32)
        plan = plan_matmul_tiles(M, K, N)
        y = gpp_matmul(x, w, interpret=True)
        err = float(jnp.max(jnp.abs(y - matmul_ref(x, w))))
        assert err < 5e-3, err
        return plan, err

    us, (plan, err) = _timed(run_after)
    _record(
        "kernel_tiled_vmem_after", us,
        f"M{M}xK{K}xN{N}_blocks={plan.block_m}/{plan.block_n}/{plan.block_k}"
        f"_G={plan.num_bufs}_vmem={plan.vmem_bytes / 2**20:.0f}MiB"
        f"_maxerr={err:.1e}")


def bench_dense_attn_projection():
    """Unified dense() routing for attention projections: interpret-mode
    parity of a dhk-shaped q-proj and an hkd-shaped o-proj against the
    einsum path, plus jit'd ref-path latency at a serving-ish shape."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels.ops import dense

    def run():
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (8, 256), jnp.float32)
        wq = jax.random.normal(key, (256, 8, 64), jnp.float32)
        q = dense(x, wq, mode="interpret")
        np.testing.assert_allclose(
            np.asarray(q), np.asarray(jnp.einsum("bd,dhk->bhk", x, wq)),
            rtol=1e-5, atol=1e-4)
        wo = jax.random.normal(key, (8, 64, 256), jnp.float32)
        o = dense(q, wo, mode="interpret", contract_dims=2)
        np.testing.assert_allclose(
            np.asarray(o), np.asarray(jnp.einsum("bhk,hkd->bd", q, wo)),
            rtol=1e-5, atol=1e-4)
        # latency: jit'd ref path at a 2048-wide projection
        xb = jax.random.normal(key, (64, 2048), jnp.bfloat16)
        wb = jax.random.normal(key, (2048, 16, 128), jnp.bfloat16)
        f = jax.jit(lambda x, w: dense(x, w, mode="ref"))
        f(xb, wb).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(10):
            f(xb, wb).block_until_ready()
        return (time.perf_counter() - t0) / 10 * 1e6

    us, ref_us = _timed(run)
    _record("dense_attn_projection", us,
            f"qproj/oproj_interpret_allclose_refpath={ref_us:.0f}us@64x2048x2048")


def bench_dense_grouped_moe():
    """Grouped-expert streaming matmul: interpret parity vs the batched
    einsum oracle at a ragged capacity, plus jit'd ref latency."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels.ops import dense_grouped
    from repro.kernels.ref import dense_grouped_ref

    def run():
        key = jax.random.PRNGKey(0)
        E, C, D, F = 4, 13, 64, 96   # ragged C: capacity != tile multiple
        x = jax.random.normal(key, (E, C, D), jnp.float32)
        w = jax.random.normal(key, (E, D, F), jnp.float32)
        y = dense_grouped(x, w, activation="silu", mode="interpret")
        np.testing.assert_allclose(
            np.asarray(y),
            np.asarray(dense_grouped_ref(x, w, activation="silu")),
            rtol=1e-5, atol=1e-4)
        # latency: jit'd ref path at a small-expert-stack shape
        xb = jax.random.normal(key, (8, 128, 512), jnp.bfloat16)
        wb = jax.random.normal(key, (8, 512, 1024), jnp.bfloat16)
        f = jax.jit(lambda x, w: dense_grouped(x, w, mode="ref"))
        f(xb, wb).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(10):
            f(xb, wb).block_until_ready()
        return (time.perf_counter() - t0) / 10 * 1e6

    us, ref_us = _timed(run)
    _record("dense_grouped_moe", us,
            f"E4xC13_ragged_interpret_allclose_refpath={ref_us:.0f}us@8x128x512x1024")


def bench_dense_timing_samples():
    """Measure per-tile t_dma/t_compute on THIS host and mirror the samples
    into BENCH_kernels.json for `core.schedule.TimingCache.from_bench_json`
    — the measured-feedback loop that replaces the planner's analytic
    PEAK_FLOPS/HBM_BYTES_PER_S constants with reality."""
    import jax
    import jax.numpy as jnp
    from repro.core.schedule import TimingCache, plan_matmul_tiles

    # tile large enough (8 MiB weights) that the transfer/compute dwarfs
    # per-call dispatch overhead; a no-op baseline is subtracted anyway.
    bm, bk, bn = 256, 4096, 512
    tile_bytes = bk * bn * 4
    tile_flops = 2.0 * bm * bk * bn
    REPS = 8

    def run():
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (bm, bk), jnp.float32)
        w = jax.random.normal(key, (bk, bn), jnp.float32)
        z = jnp.zeros(())
        mm = jax.jit(lambda a, b: a @ b)
        cp = jax.jit(lambda a: a + 0.0)   # device-memory round trip ~ "DMA"
        noop = jax.jit(lambda a: a)       # dispatch-overhead baseline

        def batch_time(fn, *args):
            fn(*args).block_until_ready()             # warm/compile
            t0 = time.perf_counter()
            for _ in range(REPS):
                fn(*args).block_until_ready()
            return (time.perf_counter() - t0) / REPS

        # provenance: these jit'd timing loops measure a COMPILED path, so on
        # an accelerator backend the samples rank as "compiled" and
        # `TimingCache.effective_rates` will prefer them over host samples;
        # on the CPU host they are tagged "host" (dispatch-dominated, no real
        # HBM link) and only stand in until a TPU run refreshes the record.
        provenance = ("compiled" if jax.default_backend() in ("tpu", "gpu")
                      else "host")
        tc = TimingCache()
        for _ in range(5):
            base = batch_time(noop, z)
            t_cmp = max(batch_time(mm, x, w) - base, 1e-9)
            t_dma = max(batch_time(cp, w) - base, 1e-9)
            tc.record(block_bytes=tile_bytes, compute_flops=tile_flops,
                      t_dma=t_dma, t_compute=t_cmp, measured_on=provenance)
        analytic = plan_matmul_tiles(8, 4096, 8192)
        measured = plan_matmul_tiles(8, 4096, 8192, timing=tc)
        fps, bps = tc.effective_rates()
        return tc, analytic, measured, fps, bps, provenance

    us, (tc, analytic, measured, fps, bps, provenance) = _timed(run)
    _record(
        "dense_timing_samples", us,
        f"measured_flops={fps:.2e}_bytes={bps:.2e}"
        f"_ring_analytic={analytic.num_bufs}_measured={measured.num_bufs}"
        f"_on={provenance}",
        extra={"samples": tc.to_json(), "measured_on": provenance})


def bench_serving_paged_vs_dense():
    """Serving: paged-KV chunked-prefill engine vs the seed dense-cache
    engine on a mixed prefill/decode trace with a saturating queue, at EQUAL
    block-memory budget (paged pool = slots x max_len tokens, shared).

    Headline: aggregate tokens/sec speedup (target >= 1.5x) and the per-step
    token-count flatness (coefficient of variation; the GPP claim is that
    chunking the prefill burst flattens per-step traffic)."""
    import jax
    import numpy as np
    from repro.models import registry
    from repro.models import transformer as tf
    from repro.serving import DenseServingEngine, ServeConfig, ServingEngine

    SLOTS, MAX_LEN, REQUESTS, MAX_NEW = 4, 128, 16, 12
    cfg = registry.get_config("qwen1.5-0.5b", smoke=True)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))

    def trace(engine):
        # saturating queue: every request submitted before the first step,
        # prompt lengths drawn from a wide mix (re-jit worst case)
        rng = np.random.default_rng(0)
        rids = [engine.submit(
            rng.integers(0, cfg.vocab_size, size=int(n)).tolist(),
            max_new_tokens=MAX_NEW)
            for n in rng.integers(4, 60, size=REQUESTS)]
        t0 = time.perf_counter()
        results = engine.run()
        dt = time.perf_counter() - t0
        tokens = sum(len(results[r]) for r in rids)
        assert len(results) == REQUESTS
        return tokens / dt, engine.flatness_cov(), dt

    serve = ServeConfig(slots=SLOTS, max_len=MAX_LEN)
    tps_dense, cov_dense, dt_dense = trace(
        DenseServingEngine(cfg, params, serve))
    paged = ServingEngine(cfg, params, serve)
    tps_paged, cov_paged, dt_paged = trace(paged)

    speedup = tps_paged / tps_dense
    _record_serving(
        "serving_paged_vs_dense", dt_paged * 1e6,
        f"speedup={speedup:.2f}x_tok/s={tps_paged:.0f}vs{tps_dense:.0f}"
        f"_cov={cov_paged:.3f}vs{cov_dense:.3f}",
        extra={
            "tokens_per_s_paged": round(tps_paged, 1),
            "tokens_per_s_dense": round(tps_dense, 1),
            "speedup": round(speedup, 3),
            "tokens_per_step_cov_paged": round(cov_paged, 4),
            "tokens_per_step_cov_dense": round(cov_dense, 4),
            "slots": SLOTS, "max_len": MAX_LEN,
            "block_size": paged.block_size, "prefill_chunk": paged.chunk,
            "num_blocks": paged.kv.cfg.num_blocks,
            "requests": REQUESTS, "max_new": MAX_NEW,
            "trace_counts_paged": dict(paged.trace_counts),
        })


def bench_serving_step_metrics():
    """Per-step metric export: blocks in use / queue depth / projected HBM
    bytes from the paged engine on a short saturating burst."""
    import jax
    import numpy as np
    from repro.core.schedule import tokens_per_step_cov
    from repro.models import registry
    from repro.models import transformer as tf
    from repro.serving import ServeConfig, ServingEngine

    cfg = registry.get_config("qwen1.5-0.5b", smoke=True)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))

    def run():
        eng = ServingEngine(cfg, params, ServeConfig(slots=2, max_len=64))
        rng = np.random.default_rng(1)
        for n in (24, 17, 9, 30):
            eng.submit(rng.integers(0, cfg.vocab_size, size=n).tolist(),
                       max_new_tokens=6)
        eng.run()
        peak_blocks = max(m["blocks_in_use"] for m in eng.metrics)
        peak_q = max(m["queue_depth"] for m in eng.metrics)
        # the per-step flatness/utilization column now comes from the
        # typed ledger (obs.ledger.BandwidthLedger), not a hand tally
        util = eng.metrics.utilization_report()
        assert abs(util["hbm_bytes_per_step_cov"] - tokens_per_step_cov(
            [m["hbm_bytes"] for m in eng.metrics])) < 1e-9
        return eng, peak_blocks, peak_q, util

    us, (eng, peak_blocks, peak_q, util) = _timed(run)
    bytes_cov = util["hbm_bytes_per_step_cov"]
    _record_serving(
        "serving_step_metrics", us,
        f"steps={len(eng.metrics)}_peak_blocks={peak_blocks}"
        f"_peak_queue={peak_q}_hbm_bytes_cov={bytes_cov:.3f}",
        extra={"steps": len(eng.metrics), "peak_blocks_in_use": peak_blocks,
               "peak_queue_depth": peak_q,
               "hbm_bytes_per_step_cov": round(bytes_cov, 4),
               "measured_bw_utilization":
                   round(util["measured_bw_utilization"], 4),
               "predicted_bw_utilization":
                   round(util["predicted_bw_utilization"], 4)})


def bench_serving_paged_attn_gather_vs_kernel():
    """Paged-attention read path: gather (materialize every lane's logical
    sequence in HBM) vs the block-table Pallas kernel (stream live KV blocks
    through a VMEM ring).

    Headlines: per-step attention-read HBM bytes (materialized by the gather
    vs moved by the kernel ring — live blocks only) and tokens/sec under the
    "auto" routing, which must be no worse than the explicit gather path
    (identical on a CPU host where auto resolves to ref; the kernel takes
    over on TPU).  Kernel numerics are validated with a short interpret-mode
    engine run that must reproduce the gather engine's tokens exactly."""
    import jax
    import numpy as np
    from repro.models import registry
    from repro.models import transformer as tf
    from repro.serving import ServeConfig, ServingEngine

    cfg = registry.get_config("qwen1.5-0.5b", smoke=True)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    SLOTS, MAX_LEN, REQUESTS, MAX_NEW = 4, 128, 12, 10

    def trace(mode, requests=REQUESTS, max_new=MAX_NEW):
        eng = ServingEngine(cfg, params, ServeConfig(
            slots=SLOTS, max_len=MAX_LEN, paged_attn_kernel=mode))
        # warm-up: compile both step shapes before the timed trace, so
        # tokens/sec compares the steady-state read paths, not jit time
        eng.submit([1, 2, 3], max_new_tokens=2)
        eng.run()
        rng = np.random.default_rng(0)
        rids = [eng.submit(
            rng.integers(0, cfg.vocab_size, size=int(n)).tolist(),
            max_new_tokens=max_new)
            for n in rng.integers(4, 60, size=requests)]
        t0 = time.perf_counter()
        results = eng.run()
        dt = time.perf_counter() - t0
        tokens = sum(len(results[r]) for r in rids)
        return [results[r] for r in rids], tokens / dt, eng

    # best-of-2 per mode: on a CPU host both modes resolve to the same ref
    # path, so tokens/sec differences are scheduler noise — de-noise before
    # asserting the "auto no worse" headline
    streams_ref, tps_gather, eng_ref = trace("ref")
    streams_auto, tps_auto, eng_auto = trace("auto")
    tps_gather = max(tps_gather, trace("ref")[1])
    tps_auto = max(tps_auto, trace("auto")[1])
    if eng_auto.paged_attn_mode == "ref":
        # same resolved path (CPU host): streams must be token-identical.
        # On TPU auto takes the pallas kernel, whose reassociated f32 math
        # may legitimately flip an argmax — the interpret-parity check
        # below is the numerics gate there.
        assert streams_auto == streams_ref, "auto routing changed outputs"
    # interpret-mode kernel parity on a short slice of the same trace
    streams_ki, _, _ = trace("interpret", requests=2, max_new=3)
    streams_rs, _, _ = trace("ref", requests=2, max_new=3)
    assert streams_ki == streams_rs, "kernel parity failed"

    gather = float(np.mean([m["attn_bytes_gather"] for m in eng_ref.metrics]))
    stream = float(np.mean([m["attn_bytes_stream"] for m in eng_ref.metrics]))
    reduction = gather / max(stream, 1.0)
    _record_serving(
        "serving_paged_attn_gather_vs_kernel", 0.0,
        f"attn_bytes/step_gather={gather:.0f}_kernel={stream:.0f}"
        f"_reduction={reduction:.2f}x_tok/s_auto={tps_auto:.0f}"
        f"vs_gather={tps_gather:.0f}_kernel_parity=ok",
        extra={
            "attn_bytes_per_step_gather": round(gather, 1),
            "attn_bytes_per_step_kernel": round(stream, 1),
            "bytes_reduction": round(reduction, 3),
            "tokens_per_s_gather": round(tps_gather, 1),
            "tokens_per_s_auto": round(tps_auto, 1),
            "paged_attn_mode_auto": eng_auto.paged_attn_mode,
            "kernel_interpret_parity": True,
            "slots": SLOTS, "max_len": MAX_LEN, "requests": REQUESTS,
            "max_new": MAX_NEW,
        })


def bench_serving_prefix_reuse():
    """Shared-prefix KV reuse (serving/prefix.py) on a multi-turn trace:
    every conversation opens with the same system prompt, and each second
    turn replays the full first turn plus a follow-up — the redundant
    re-prefill the radix index exists to eliminate.

    Headlines: prefill HBM bytes (KV writes for every prefilled chunk token
    + one weight stream per prefill-carrying step) and tokens/sec, with vs
    without sharing, at TOKEN-IDENTICAL outputs (asserted).  With sharing,
    matched prefix blocks are mapped via the block tables instead of
    recomputed, so the with-sharing trace must show strictly fewer prefill
    bytes at equal output."""
    import jax
    import numpy as np
    from repro.models import registry
    from repro.models import transformer as tf
    from repro.serving import ServeConfig, ServingEngine

    cfg = registry.get_config("qwen1.5-0.5b", smoke=True)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    SLOTS, MAX_LEN, USERS, MAX_NEW = 2, 128, 4, 6
    system = list(range(200, 232))          # 32-token shared system prompt

    def trace(prefix):
        eng = ServingEngine(cfg, params, ServeConfig(
            slots=SLOTS, max_len=MAX_LEN, prefix_cache=prefix))
        # warm-up: compile both step shapes outside the timed region; the
        # second prompt overlaps the first so a COW tail fork (and its
        # jitted pool-copy) also compiles before timing starts
        eng.submit([1, 2, 3], max_new_tokens=2)
        eng.run()
        eng.submit([1, 2, 3, 5, 6], max_new_tokens=2)
        eng.run()
        base_steps = len(eng.metrics)
        rng = np.random.default_rng(0)
        streams = []
        t0 = time.perf_counter()
        turn1 = {}
        for u in range(USERS):              # turn 1: shared system prompt
            p = system + rng.integers(0, cfg.vocab_size, size=6).tolist()
            rid = eng.submit(p, max_new_tokens=MAX_NEW)
            eng.run()
            turn1[u] = (p, eng.result(rid))
            streams.append(eng.result(rid))
        for u in range(USERS):              # turn 2: full history replayed
            p1, out1 = turn1[u]
            p = p1 + out1 + rng.integers(0, cfg.vocab_size, size=4).tolist()
            rid = eng.submit(p, max_new_tokens=MAX_NEW)
            eng.run()
            streams.append(eng.result(rid))
        dt = time.perf_counter() - t0
        ms = eng.metrics[base_steps:]
        prefill_bytes = sum(
            m["prefill_tokens"] * eng._kv_token_bytes
            + (eng._param_bytes if m["prefill_tokens"] else 0)
            for m in ms)
        hit_tokens = sum(m["prefix_hit_tokens"] for m in ms)
        tokens = sum(len(s) for s in streams)
        return streams, tokens / dt, prefill_bytes, hit_tokens, eng

    cold_streams, tps_cold, bytes_cold, _, _ = trace(False)
    warm_streams, tps_warm, bytes_warm, hit_tokens, eng = trace(True)
    assert warm_streams == cold_streams, "prefix sharing changed outputs"
    assert hit_tokens > 0, "multi-turn trace produced no cache hits"
    assert bytes_warm < bytes_cold, \
        "sharing must strictly reduce prefill HBM bytes at equal output"
    _record_serving(
        "serving_prefix_reuse", 0.0,
        f"prefill_bytes_shared={bytes_warm:.2e}_vs_cold={bytes_cold:.2e}"
        f"_({bytes_cold / bytes_warm:.2f}x_fewer)_tok/s={tps_warm:.0f}"
        f"vs{tps_cold:.0f}_hit_tokens={hit_tokens}"
        f"_hit_rate={eng.prefix.hit_rate():.2f}",
        extra={
            "prefill_hbm_bytes_shared": bytes_warm,
            "prefill_hbm_bytes_cold": bytes_cold,
            "prefill_bytes_reduction": round(bytes_cold / bytes_warm, 3),
            "tokens_per_s_shared": round(tps_warm, 1),
            "tokens_per_s_cold": round(tps_cold, 1),
            "prefix_hit_tokens": hit_tokens,
            "prefix_hit_rate": round(eng.prefix.hit_rate(), 3),
            "outputs_token_identical": True,
            "slots": SLOTS, "max_len": MAX_LEN, "users": USERS,
            "max_new": MAX_NEW, "system_prompt_tokens": len(system),
        })


def bench_serving_speculative():
    """Speculative decoding (self-drafted n-gram drafts + one batched verify
    pass of draft_len+1 tokens per lane) on a decode-heavy repetitive trace.

    Decode streams the full weight working set per step for ONE new token
    per lane — the worst bytes-per-useful-token regime in the GPP ledger.
    Accepted drafts amortize that same stream over up to draft_len+1 emitted
    tokens, so the headline is HBM bytes per EMITTED token, speculation on
    vs off, at TOKEN-IDENTICAL outputs (asserted).  In the bandwidth-bound
    deployment regime the paper targets, tokens/sec is the inverse of that
    ledger, so the asserted >=1.5x throughput speedup is the PROJECTED
    (bandwidth-bound) one from the deterministic byte counts; measured
    wall-clock tokens/sec on this smoke-scale compute-bound host is
    recorded alongside for reference (noisy, not asserted)."""
    import jax
    import numpy as np
    from repro.models import registry
    from repro.models import transformer as tf
    from repro.serving import ServeConfig, ServingEngine

    cfg = registry.get_config("qwen1.5-0.5b", smoke=True)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    SLOTS, MAX_LEN, MAX_NEW, DRAFT_LEN = 2, 128, 48, 4
    rng = np.random.default_rng(0)
    # repetitive prompts (chat boilerplate / structured output stand-in):
    # prompt-lookup drafting feeds on exactly this kind of local repetition
    prompts = [np.tile(rng.integers(0, cfg.vocab_size, size=4), 8).tolist()
               for _ in range(4)]

    def trace(spec):
        eng = ServingEngine(cfg, params, ServeConfig(
            slots=SLOTS, max_len=MAX_LEN, speculation=spec,
            draft_len=DRAFT_LEN if spec else 0))
        # warm-up: compile every step shape outside the timed region
        eng.submit(np.tile([7, 9], 8).tolist(), max_new_tokens=12)
        eng.run()
        # min-of-3 identical waves: wall-clock on a shared host is noisy,
        # the engine's work per wave (steps, bytes fed) is deterministic
        best_dt, streams, ms = float("inf"), None, None
        for _ in range(3):
            base_steps = len(eng.metrics)
            t0 = time.perf_counter()
            rids = [eng.submit(p, max_new_tokens=MAX_NEW) for p in prompts]
            eng.run()
            dt = time.perf_counter() - t0
            wave = [eng.result(r) for r in rids]
            assert streams is None or wave == streams
            if dt < best_dt:
                best_dt, streams, ms = dt, wave, eng.metrics[base_steps:]
        emitted = sum(len(s) for s in streams)
        hbm_per_tok = sum(m["hbm_bytes"] for m in ms) / emitted
        return streams, emitted / best_dt, hbm_per_tok, len(ms), eng

    off_streams, tps_off, hbm_off, steps_off, _ = trace(False)
    on_streams, tps_on, hbm_on, steps_on, eng = trace(True)
    assert on_streams == off_streams, "speculation changed the output stream"
    acc = eng.acceptance_rate()
    assert acc > 0, "repetitive trace produced no accepted drafts"
    assert hbm_on < hbm_off, \
        "accepted drafts must cut HBM bytes per emitted token"
    bw_speedup = hbm_off / hbm_on   # tokens/sec ratio when HBM-bound
    assert bw_speedup >= 1.5, \
        f"bandwidth-bound speedup {bw_speedup:.2f}x below the 1.5x target"
    _record_serving(
        "serving_speculative", 0.0,
        f"bw_bound_speedup={bw_speedup:.2f}x_hbm_B/tok={hbm_on:.2e}"
        f"_vs_{hbm_off:.2e}_acceptance={acc:.2f}"
        f"_steps={steps_on}vs{steps_off}"
        f"_wallclock_tok/s={tps_on:.0f}vs{tps_off:.0f}",
        extra={
            "bandwidth_bound_speedup": round(bw_speedup, 3),
            "hbm_bytes_per_emitted_token_spec": round(hbm_on, 1),
            "hbm_bytes_per_emitted_token_off": round(hbm_off, 1),
            "tokens_per_s_spec_wallclock": round(tps_on, 1),
            "tokens_per_s_off_wallclock": round(tps_off, 1),
            "acceptance_rate": round(acc, 3),
            "steps_spec": steps_on, "steps_off": steps_off,
            "tokens_per_step_cov_spec": round(eng.flatness_cov(), 3),
            "outputs_token_identical": True,
            "slots": SLOTS, "max_len": MAX_LEN, "max_new": MAX_NEW,
            "draft_len": DRAFT_LEN, "draft_source": "self",
        })


def bench_serving_observability_overhead():
    """Telemetry cost regression gate: tokens/sec with full observability
    (trace spans + ledger wall times + TTFT/TPOT histograms) vs disabled,
    on identical request waves with token-identical outputs (asserted).

    The obs subsystem's contract is near-zero overhead when off and < 5%
    when ON; this bench asserts the enabled side.  The entry is tagged with
    the TimingCache provenance of the rates the schedule planners consumed
    (BENCH_kernels.json dense_timing_samples): host-only samples mean the
    ratios were planned from host-process timings — the carried-forward
    ROADMAP caveat — so a warning is printed when zero `measured_on:
    compiled` samples exist."""
    import warnings

    import jax
    import numpy as np
    from repro.core.schedule import TimingCache
    from repro.models import registry
    from repro.models import transformer as tf
    from repro.serving import ServeConfig, ServingEngine

    cfg = registry.get_config("qwen1.5-0.5b", smoke=True)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    SLOTS, MAX_LEN, MAX_NEW, REPS = 2, 128, 48, 5
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=int(n)).tolist()
               for n in rng.integers(6, 40, size=12)]

    def make(obs_on):
        eng = ServingEngine(cfg, params, ServeConfig(
            slots=SLOTS, max_len=MAX_LEN, obs=obs_on))
        # warm-up wave compiles both step shapes outside the timed region
        eng.submit([3, 1, 4, 1, 5], max_new_tokens=8)
        eng.run()
        return eng

    # the two engines' waves are INTERLEAVED so slow machine drift (cpu
    # frequency, co-tenants) hits both sides alike instead of aliasing
    # into the comparison; min-of-REPS then discards one-sided contention
    # noise while preserving the additive telemetry cost being measured
    engs = {False: make(False), True: make(True)}
    streams = {False: None, True: None}

    def measure():
        wall = {False: [], True: []}
        for _ in range(REPS):
            for obs_on in (False, True):
                eng = engs[obs_on]
                t0 = time.perf_counter()
                rids = [eng.submit(p, max_new_tokens=MAX_NEW)
                        for p in prompts]
                eng.run()
                dt = time.perf_counter() - t0
                wave = [eng.result(r) for r in rids]
                assert streams[obs_on] is None or wave == streams[obs_on], \
                    "waves are deterministic"
                streams[obs_on] = wave
                wall[obs_on].append(dt)
        return min(wall[False]), min(wall[True])

    # wall-clock on a shared host is one-sided noisy even after the
    # interleave + min: gate on the best of a few measurement attempts
    # (contention only ever inflates a reading, never deflates it)
    attempts = [measure()]
    while attempts[-1][1] / attempts[-1][0] - 1.0 >= 0.05 \
            and len(attempts) < 3:
        attempts.append(measure())
    off_wall, on_wall = min(attempts, key=lambda a: a[1] / a[0])
    overhead = on_wall / off_wall - 1.0
    assert streams[True] == streams[False], \
        "telemetry changed the output stream"
    emitted = sum(len(s) for s in streams[False])
    tps_off, tps_on = emitted / off_wall, emitted / on_wall
    eng = engs[True]
    assert len(eng.obs.trace) > 0 and eng.obs.requests.summary()[
        "ttft"]["count"] > 0, "obs run recorded no telemetry"
    assert overhead < 0.05, \
        f"observability overhead {overhead:.1%} breaches the 5% budget " \
        f"(attempts: {[f'{on / off - 1.0:.1%}' for off, on in attempts]})"

    # TimingCache provenance of the planner rates this run used
    tc = TimingCache.from_bench_json(BENCH_JSON)
    provs = [s.measured_on for s in tc.samples] if tc is not None else []
    compiled = sum(1 for p in provs if p == "compiled")
    if not compiled:
        warnings.warn(
            "no `measured_on: compiled` TimingCache samples in "
            f"{BENCH_JSON}: planner rates derive from host-process timings "
            "(run bench_dense_timing_samples on a compiled backend)",
            stacklevel=2)
    _record_serving(
        "serving_observability_overhead", 0.0,
        f"overhead={overhead:.1%}_tok/s={tps_on:.0f}vs{tps_off:.0f}"
        f"_trace_events={len(eng.obs.trace)}",
        extra={
            "tokens_per_s_obs_on": round(tps_on, 1),
            "tokens_per_s_obs_off": round(tps_off, 1),
            "overhead_fraction": round(overhead, 4),
            "overhead_budget": 0.05,
            "outputs_token_identical": True,
            "trace_events": len(eng.obs.trace),
            "trace_dropped": eng.obs.trace.dropped,
            "timing_provenances": sorted(set(provs)),
            "timing_compiled_samples": compiled,
            "slots": SLOTS, "max_len": MAX_LEN, "max_new": MAX_NEW,
            "requests": len(prompts), "reps_interleaved": REPS,
        })


def main() -> None:
    print("name,us_per_call,derived")
    try:
        bench_fig4_naive_pp_utilization()
        bench_fig6_design_phase()
        bench_fig7_runtime_adaptation()
        bench_table2_theory_practice()
        bench_headline_1_67x()
        bench_kernel_gpp_matmul()
        bench_kernel_cycle_model()
        bench_kernel_tiled_vmem()
        bench_dense_attn_projection()
        bench_dense_grouped_moe()
        bench_dense_timing_samples()
        bench_serving_paged_vs_dense()
        bench_serving_step_metrics()
        bench_serving_paged_attn_gather_vs_kernel()
        bench_serving_prefix_reuse()
        bench_serving_speculative()
        bench_serving_observability_overhead()
        bench_streamer_modes()
    finally:
        # keep the partial perf record even if one benchmark dies mid-run
        with open(BENCH_JSON, "w") as f:
            json.dump(RESULTS, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {BENCH_JSON} ({len(RESULTS)} entries)")
        if SERVING_RESULTS:
            _append_json(BENCH_SERVING_JSON, SERVING_RESULTS)


if __name__ == "__main__":
    main()
