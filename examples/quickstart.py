"""Quickstart: train a tiny model for a few steps, then generate from it.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/quickstart.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models import registry
from repro.models import transformer as tf
from repro.optim import adamw


def main():
    cfg = registry.get_config("qwen1.5-0.5b", smoke=True)
    mesh = make_host_mesh(2, 2)
    shape = ShapeConfig("quickstart", seq_len=64, global_batch=8, kind="train")

    with jax.set_mesh(mesh):
        bundle = make_train_step(cfg, mesh, shape)
        params = jax.device_put(tf.init_params(cfg, jax.random.PRNGKey(0)),
                                bundle.arg_shardings[0])
        opt_state = jax.device_put(adamw.adamw_init(params),
                                   bundle.arg_shardings[1])
        pipe = TokenPipeline(cfg, DataConfig(batch=8, seq_len=64))
        for step in range(10):
            batch = {k: jax.device_put(v, bundle.arg_shardings[2][k])
                     for k, v in pipe.batch_at(step).items()}
            params, opt_state, metrics = bundle.fn(
                params, opt_state, batch, jnp.asarray(step))
            print(f"step {step}: loss {float(metrics['loss']):.4f}")

        # generate a few tokens greedily
        prompt = jnp.array([[1, 5, 42, 7]], jnp.int32)
        logits, caches = tf.prefill(params, cfg, {"tokens": prompt}, max_len=32)
        toks = [int(jnp.argmax(logits[0, -1]))]
        for pos in range(prompt.shape[1], prompt.shape[1] + 8):
            logits, caches = tf.decode_step(
                params, cfg, jnp.array([[toks[-1]]], jnp.int32), caches, pos)
            toks.append(int(jnp.argmax(logits[0, -1])))
        print("generated:", toks)


if __name__ == "__main__":
    main()
