"""Serve a small model with batched requests through the paged-KV
continuous-batching engine (chunked prefill + block-table decode).

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import numpy as np

import jax

from repro.models import registry
from repro.models import transformer as tf
from repro.serving.engine import ServeConfig, ServingEngine


def main():
    cfg = registry.get_config("qwen1.5-0.5b", smoke=True)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, ServeConfig(slots=4, max_len=96))

    rng = np.random.default_rng(0)
    rids = [engine.submit(rng.integers(0, cfg.vocab_size,
                                       size=rng.integers(4, 16)).tolist(),
                          max_new_tokens=12)
            for _ in range(8)]

    t0 = time.time()
    results = engine.run()
    dt = time.time() - t0
    tokens = sum(len(v) for v in results.values())
    for rid in rids:
        assert len(results[rid]) >= 1
        print(f"req {rid}: {results[rid]}")
    print(f"{tokens} tokens across {len(rids)} requests in {dt:.2f}s "
          f"({tokens/dt:.1f} tok/s, continuous batching over 4 slots)")
    print(f"tokens/step cov={engine.flatness_cov():.3f} "
          f"(chunk={engine.chunk}, block={engine.block_size}, "
          f"compiled shapes={engine.trace_counts})")


if __name__ == "__main__":
    main()
