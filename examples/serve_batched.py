"""Serve a small model with batched requests through the paged-KV
continuous-batching engine (chunked prefill + block-table decode), then a
multi-turn round with the radix-tree prefix cache: every conversation opens
with the same system prompt and each follow-up turn replays its full
history, so the engine maps the matched KV blocks straight into the lane's
tables and prefills only the novel suffix.  Finally a speculative-decoding
round: self-drafted prompt-lookup n-grams ride one batched verify step per
schedule tick, emitting up to draft_len+1 tokens per lane per weight
stream — with the output stream token-identical to plain decode.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import numpy as np

import jax

from repro.models import registry
from repro.models import transformer as tf
from repro.serving.engine import ServeConfig, ServingEngine


def main():
    cfg = registry.get_config("qwen1.5-0.5b", smoke=True)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, ServeConfig(slots=4, max_len=96))

    rng = np.random.default_rng(0)
    rids = [engine.submit(rng.integers(0, cfg.vocab_size,
                                       size=rng.integers(4, 16)).tolist(),
                          max_new_tokens=12)
            for _ in range(8)]

    t0 = time.time()
    results = engine.run()
    dt = time.time() - t0
    tokens = sum(len(v) for v in results.values())
    for rid in rids:
        assert len(results[rid]) >= 1
        print(f"req {rid}: {results[rid]}")
    print(f"{tokens} tokens across {len(rids)} requests in {dt:.2f}s "
          f"({tokens/dt:.1f} tok/s, continuous batching over 4 slots)")
    print(f"tokens/step cov={engine.flatness_cov():.3f} "
          f"(chunk={engine.chunk}, block={engine.block_size}, "
          f"compiled shapes={engine.trace_counts})")

    # ---- multi-turn with shared-prefix KV reuse --------------------------
    eng = ServingEngine(cfg, params, ServeConfig(
        slots=2, max_len=96, prefix_cache=True))
    system = list(range(100, 132))        # 32-token shared system prompt
    history = {}
    for user in range(3):                 # turn 1: same system prompt
        prompt = system + rng.integers(0, cfg.vocab_size, size=5).tolist()
        rid = eng.submit(prompt, max_new_tokens=8)
        eng.run()
        history[user] = prompt + eng.result(rid)
    for user in range(3):                 # turn 2: full history replayed
        prompt = history[user] + rng.integers(0, cfg.vocab_size,
                                              size=4).tolist()
        rid = eng.submit(prompt, max_new_tokens=8)
        eng.run()
        assert len(eng.result(rid)) == 8
    hit_tokens = sum(m["prefix_hit_tokens"] for m in eng.metrics)
    shared_peak = max(m["blocks_shared"] for m in eng.metrics)
    print(f"prefix cache: hit_rate={eng.prefix_hit_rate():.2f} "
          f"hit_tokens={hit_tokens} peak_shared_blocks={shared_peak} "
          f"(turn-2 prefills skipped their replayed history)")
    assert eng.prefix_hit_rate() > 0 and hit_tokens >= len(system)

    # ---- speculative decoding (self-drafted, batched verify) -------------
    reps = [np.tile([5, 6, 7, 8], 6).tolist(), np.tile([9, 3], 10).tolist()]

    def decode(spec):
        eng = ServingEngine(cfg, params, ServeConfig(
            slots=2, max_len=96, speculation=spec, draft_len=4 if spec else 0))
        rids = [eng.submit(p, max_new_tokens=16) for p in reps]
        res = eng.run()
        return [res[r] for r in rids], eng

    plain, plain_eng = decode(False)
    spec, eng = decode(True)
    assert spec == plain                  # speculation never changes output
    print(f"speculation: acceptance_rate={eng.acceptance_rate():.2f} "
          f"steps={len(eng.metrics)} vs {len(plain_eng.metrics)} plain "
          f"(same token streams), compiled shapes={eng.trace_counts}")

    # ---- observability: demo trace + metrics snapshot --------------------
    # obs=True turns on the telemetry layer (docs/OBSERVABILITY.md): async
    # request spans, per-step/phase spans, modeled kernel DMA/compute
    # lanes, and TTFT/TPOT histograms — the token stream is unchanged.
    eng = ServingEngine(cfg, params, ServeConfig(slots=2, max_len=96,
                                                 obs=True))
    for _ in range(4):
        eng.submit(rng.integers(0, cfg.vocab_size,
                                size=rng.integers(4, 16)).tolist(),
                   max_new_tokens=8)
    eng.run()
    eng.obs.write_trace("serve_trace.json")
    snap = eng.obs.write_metrics("serve_metrics.jsonl",
                                 extra={"ledger": eng.metrics.summary()})
    ttft = snap["requests"]["ttft"]
    util = eng.metrics.utilization_report()
    print(f"observability: {len(eng.obs.trace)} trace events -> "
          f"serve_trace.json (load at https://ui.perfetto.dev), "
          f"ttft_p50={ttft['p50'] * 1e3:.1f}ms, bw_utilization "
          f"measured={util['measured_bw_utilization']:.2f} vs "
          f"predicted={util['predicted_bw_utilization']:.2f}")


if __name__ == "__main__":
    main()
