"""The paper's design-phase + runtime-phase workflow as a worked example.

Given an off-chip bandwidth budget, size a PIM accelerator with each
write/compute schedule (Eqs 3-4), compare their throughput on a consecutive
GeMM workload with the cycle-accurate simulator (Fig 6), then cut bandwidth
at "runtime" and watch each schedule adapt (Fig 7 / Eqs 7-9).

    PYTHONPATH=src python examples/gpp_design_space.py
"""
import math

import repro.core.analytical as ana
from repro.core import simulator as sim
from repro.core.analytical import PimConfig
from repro.core.runtime_adapt import adapt_gpp, adapt_insitu, adapt_naive_pp


def main():
    print("=== design phase: band=128 B/cycle, macro 1 KiB, OU 32 B, s=4 ===")
    base = PimConfig(band=128.0, s=4.0)
    for ratio in (1 / 7, 1.0, 4.0):
        c = base.with_(n_in=base.size_ou / (base.s * ratio))
        print(f"\n  t_rw:t_pim = {ratio:.3f}  (n_in={c.n_in:.0f})")
        rows = []
        for strat in ("insitu", "naive_pp", "gpp"):
            n = max(1, round(ana.num_macros(c, strat)))
            work = 48 * max(1, round(ana.num_macros(c, "gpp")))
            r = sim.simulate(strat, c, n, math.ceil(work / n))
            lat = r.total_cycles / (n * r.rounds)
            rows.append((strat, n, lat, r.peak_bandwidth, r.macro_utilization))
        best = min(r[2] for r in rows)
        for strat, n, lat, peak, util in rows:
            print(f"    {strat:9s} macros={n:4d} latency/round={lat:8.1f}cy "
                  f"(x{lat/best:4.2f}) peakBW={peak:6.1f} util={util:.2f}")

    print("\n=== runtime phase: bandwidth cut to band/n (design @ t_rw==t_pim) ===")
    cfg = PimConfig(size_macro=1024, size_ou=32, s=8.0, band=512.0)
    print(f"  {'n':>4} {'gpp':>8} {'naive':>8} {'insitu':>8}   (remaining perf, DES)")
    for n in (2, 8, 32, 64):
        g = adapt_gpp(cfg, float(n), rounds=32)
        na = adapt_naive_pp(cfg, float(n), rounds=32)
        i = adapt_insitu(cfg, float(n), rounds=32)
        print(f"  {n:4d} {g.perf_sim:8.4f} {na.perf_sim:8.4f} {i.perf_sim:8.4f}"
              f"   gpp keeps {g.perf_sim/na.perf_sim:.1f}x naive, "
              f"{g.perf_sim/i.perf_sim:.1f}x insitu")
    print("\npaper headline at n=64: 5.38x over in-situ — reproduced above.")


if __name__ == "__main__":
    main()
