"""End-to-end training driver: a ~100M-param dense LM trained for a few
hundred steps on the synthetic pipeline, with checkpoint/resume and the GPP
weight-streaming executor selectable.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/train_e2e.py --steps 300

The loss should drop from ~ln(32000)=10.4 toward the synthetic corpus'
Zipfian entropy (~5.4) within a few hundred steps.
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.checkpoint.manager import CheckpointManager
from repro.core.streamer import StreamSettings
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models import transformer as tf
from repro.optim import adamw

# ~106M params: 10 x (attn 1.6M + mlp 4.9M) + 2 x 640*32000 embeddings
CONFIG_100M = ModelConfig(
    name="repro-100m",
    family="dense",
    d_model=640,
    num_layers=10,
    num_heads=8,
    num_kv_heads=4,
    d_ff=2560,
    vocab_size=32000,
    pattern=("dense",),
    rope_theta=1e4,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--stream-mode", default="resident",
                    choices=["resident", "insitu", "naive_pp", "gpp"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_e2e")
    args = ap.parse_args()

    cfg = CONFIG_100M.with_(stream=StreamSettings(mode=args.stream_mode))
    n = len(jax.devices())
    mesh = make_host_mesh(max(1, n // 2), 2)
    shape = ShapeConfig("e2e", args.seq, args.batch, "train")
    n_params = sum(
        int(jnp.prod(jnp.array(s.shape)))
        for s in jax.tree.leaves(tf.param_specs(cfg)))
    print(f"params: {n_params/1e6:.1f}M  mesh: {dict(mesh.shape)}  "
          f"stream: {args.stream_mode}")

    with jax.set_mesh(mesh):
        bundle = make_train_step(cfg, mesh, shape)
        params = jax.device_put(tf.init_params(cfg, jax.random.PRNGKey(0)),
                                bundle.arg_shardings[0])
        opt_state = jax.device_put(adamw.adamw_init(params),
                                   bundle.arg_shardings[1])

        mgr = CheckpointManager(args.ckpt_dir)
        start = 0
        if mgr.latest_step() is not None:
            state, start = mgr.restore(
                {"p": params, "o": opt_state},
                shardings={"p": bundle.arg_shardings[0],
                           "o": bundle.arg_shardings[1]})
            params, opt_state = state["p"], state["o"]
            print(f"resumed at step {start}")

        pipe = TokenPipeline(cfg, DataConfig(batch=args.batch,
                                             seq_len=args.seq)).start(start)
        first_loss = None
        try:
            for step in range(start, args.steps):
                batch = {k: jax.device_put(v, bundle.arg_shardings[2][k])
                         for k, v in next(pipe).items()}
                params, opt_state, metrics = bundle.fn(
                    params, opt_state, batch, jnp.asarray(step))
                loss = float(metrics["loss"])
                first_loss = first_loss if first_loss is not None else loss
                if step % 20 == 0 or step == args.steps - 1:
                    print(f"step {step:4d}  loss {loss:7.4f}")
                if step and step % 100 == 0:
                    mgr.save(step, {"p": params, "o": opt_state}, blocking=False)
        finally:
            pipe.stop()
            mgr.wait()
        mgr.save(args.steps, {"p": params, "o": opt_state})
        print(f"loss: {first_loss:.3f} -> {loss:.3f}")
        assert loss < first_loss, "training must reduce loss"


if __name__ == "__main__":
    main()
