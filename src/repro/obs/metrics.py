"""Counter/gauge/histogram registry + per-request serving latency metrics.

`MetricsRegistry` is the aggregate side of the telemetry subsystem (the
trace is the timeline side): named counters, gauges, and bounded-memory
histograms, snapshotted to plain dicts and appended to a JSONL file so a
long serving run leaves a machine-readable latency record next to the
BENCH_*.json perf trajectory.

`RequestTracker` derives the two serving SLO quantities from request
lifecycle callbacks on an injected clock:

  TTFT  time-to-first-token: submit -> first sampled token.  Under chunked
        prefill this is the quantity the scheduler's flat token budget
        trades against throughput (a bigger chunk finishes prompts sooner
        but bursts the per-step traffic).
  TPOT  time-per-output-token: mean inter-token gap after the first token
        (finish - first_token) / (tokens - 1); the decode-side SLO.

Histograms keep exact samples up to `max_samples`, then decimate
deterministically (drop every second retained sample and double the
recording stride), so memory stays bounded on unbounded runs while
percentiles remain representative; `count`/`sum` always cover every
observation.
"""
from __future__ import annotations

import json
import math
import time


def percentile(samples: "list[float]", q: float) -> float:
    """Linear-interpolated percentile (q in [0, 100]) of raw samples.

    Matches numpy's default ("linear") method; implemented here so the
    metrics path has no array dependency and the math is unit-testable.
    """
    if not samples:
        return math.nan
    if not 0.0 <= q <= 100.0:
        raise ValueError("q in [0, 100]")
    xs = sorted(samples)
    if len(xs) == 1:
        return float(xs[0])
    rank = (len(xs) - 1) * (q / 100.0)
    lo = int(rank)
    hi = min(lo + 1, len(xs) - 1)
    frac = rank - lo
    return float(xs[lo] * (1.0 - frac) + xs[hi] * frac)


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Bounded-memory histogram with exact-then-decimated samples."""

    def __init__(self, max_samples: int = 4096):
        if max_samples < 2:
            raise ValueError("max_samples >= 2")
        self.max_samples = max_samples
        self._samples: "list[float]" = []
        self._stride = 1          # record every `stride`-th observation
        self._pending = 0         # observations since the last recorded one
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        self._pending += 1
        if self._pending < self._stride:
            return
        self._pending = 0
        if len(self._samples) >= self.max_samples:
            # deterministic decimation: thin the history, slow the intake
            self._samples = self._samples[::2]
            self._stride *= 2
        self._samples.append(v)

    @property
    def samples(self) -> "tuple[float, ...]":
        return tuple(self._samples)

    def quantile(self, q: float) -> float:
        return percentile(self._samples, q)

    def summary(self) -> dict:
        return {
            "count": self.count,
            "mean": self.sum / self.count if self.count else math.nan,
            "min": self.min if self.count else math.nan,
            "max": self.max if self.count else math.nan,
            "p50": self.quantile(50.0),
            "p90": self.quantile(90.0),
            "p99": self.quantile(99.0),
            "retained_samples": len(self._samples),
        }


class MetricsRegistry:
    """Named metric store; `snapshot()` is the JSONL export unit."""

    def __init__(self):
        self._counters: "dict[str, Counter]" = {}
        self._gauges: "dict[str, Gauge]" = {}
        self._hists: "dict[str, Histogram]" = {}

    def counter(self, name: str) -> Counter:
        return self._counters.setdefault(name, Counter())

    def gauge(self, name: str) -> Gauge:
        return self._gauges.setdefault(name, Gauge())

    def histogram(self, name: str, max_samples: int = 4096) -> Histogram:
        return self._hists.setdefault(name, Histogram(max_samples))

    def snapshot(self, extra: "dict | None" = None) -> dict:
        snap = {
            "counters": {k: c.value for k, c in self._counters.items()},
            "gauges": {k: g.value for k, g in self._gauges.items()},
            "histograms": {k: h.summary() for k, h in self._hists.items()},
        }
        if extra:
            snap.update(extra)
        return snap

    def write_jsonl(self, path: str, extra: "dict | None" = None) -> dict:
        """Append one snapshot line to `path`; NaNs are serialized as null
        (strict-JSON consumers must stay able to parse the file)."""
        snap = self.snapshot(extra)
        with open(path, "a") as f:
            json.dump(_null_nans(snap), f)
            f.write("\n")
        return snap


def _null_nans(obj):
    if isinstance(obj, float) and not math.isfinite(obj):
        return None
    if isinstance(obj, dict):
        return {k: _null_nans(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_null_nans(v) for v in obj]
    return obj


class RequestTracker:
    """Per-request TTFT/TPOT derivation from engine lifecycle callbacks."""

    def __init__(self, registry: "MetricsRegistry | None" = None, clock=None):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.clock = clock or time.perf_counter
        self._submit: "dict[int, float]" = {}
        self._first: "dict[int, float]" = {}

    def on_submit(self, rid: int) -> None:
        self._submit[rid] = self.clock()
        self.registry.counter("requests_submitted").inc()

    def on_first_token(self, rid: int) -> None:
        if rid in self._first:      # resume after preemption re-samples
            return
        now = self.clock()
        self._first[rid] = now
        sub = self._submit.get(rid)
        if sub is not None:
            self.registry.histogram("ttft_s").observe(now - sub)

    def on_finish(self, rid: int, tokens: int) -> None:
        now = self.clock()
        first = self._first.pop(rid, None)
        self._submit.pop(rid, None)
        self.registry.counter("requests_completed").inc()
        self.registry.counter("tokens_emitted").inc(tokens)
        if first is not None and tokens > 1:
            self.registry.histogram("tpot_s").observe(
                (now - first) / (tokens - 1))

    def summary(self) -> dict:
        reg = self.registry
        return {
            "requests_submitted": reg.counter("requests_submitted").value,
            "requests_completed": reg.counter("requests_completed").value,
            "tokens_emitted": reg.counter("tokens_emitted").value,
            "ttft": reg.histogram("ttft_s").summary(),
            "tpot": reg.histogram("tpot_s").summary(),
        }
