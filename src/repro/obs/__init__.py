"""Unified telemetry for the serving stack: trace + ledger + metrics.

Three pillars, one handle:

  `obs.trace`    span/event recorder -> Chrome/Perfetto trace-event JSON
                 (`obs/trace.py`): request lifecycles, engine step phases,
                 modeled kernel DMA-vs-compute lanes.
  `obs.ledger`   typed per-step HBM-byte ledger (`obs/ledger.py`): THE
                 step-metrics schema both engines emit, with bounded
                 retention and a simulate_gpp predicted-vs-measured
                 utilization column.  (The ledger is owned by the engine
                 as `engine.metrics`; `Telemetry` carries the trace and
                 the latency side.)
  `obs.registry` counters/gauges/histograms + per-request TTFT/TPOT via
                 `obs.requests` (`obs/metrics.py`), exported as JSONL.

`Telemetry.disabled()` is the default: `NULL_TRACE` plus a no-op request
tracker, so every instrumentation site in the hot path costs one attribute
check (`if obs.enabled:` around anything heavier than a method call).  The
serving benchmark regression-gates the enabled-path cost at <5% tokens/sec
(`benchmarks/run.py: serving_observability_overhead`).
"""
from __future__ import annotations

import time

from repro.obs.ledger import STEP_SCHEMA, BandwidthLedger, step_row
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               RequestTracker, percentile)
from repro.obs.trace import (NULL_TRACE, PID_KERNEL, PID_REQUESTS,
                             PID_SERVING, TID_COMPUTE, TID_DMA, TID_ENGINE,
                             TID_LANE0, TraceRecorder,
                             annotate_serving_tracks)

__all__ = [
    "STEP_SCHEMA", "BandwidthLedger", "step_row",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "RequestTracker",
    "percentile",
    "NULL_TRACE", "TraceRecorder", "annotate_serving_tracks",
    "PID_SERVING", "PID_REQUESTS", "PID_KERNEL",
    "TID_ENGINE", "TID_LANE0", "TID_DMA", "TID_COMPUTE",
    "Telemetry", "make_telemetry",
]


class _NullRequests:
    """Disabled-path RequestTracker: lifecycle callbacks are free."""

    def on_submit(self, rid):
        pass

    def on_first_token(self, rid):
        pass

    def on_finish(self, rid, tokens):
        pass

    def summary(self):
        return {}


_NULL_REQUESTS = _NullRequests()


class Telemetry:
    """One handle threading the telemetry pillars through an engine."""

    def __init__(self, *, enabled: bool, trace, registry, requests, clock):
        self.enabled = enabled
        self.trace = trace
        self.registry = registry
        self.requests = requests
        self.clock = clock

    @classmethod
    def make(cls, *, trace_capacity: int = 65536, clock=None) -> "Telemetry":
        clock = clock or time.perf_counter
        registry = MetricsRegistry()
        return cls(enabled=True,
                   trace=TraceRecorder(capacity=trace_capacity, clock=clock),
                   registry=registry,
                   requests=RequestTracker(registry, clock=clock),
                   clock=clock)

    @classmethod
    def disabled(cls) -> "Telemetry":
        return cls(enabled=False, trace=NULL_TRACE, registry=None,
                   requests=_NULL_REQUESTS, clock=time.perf_counter)

    def now_us(self) -> float:
        return self.clock() * 1e6

    # ------------------------------------------------------------ export
    def write_trace(self, path: str) -> None:
        self.trace.write(path)

    def write_metrics(self, path: str, extra: "dict | None" = None) -> dict:
        """Append one snapshot line (latency summaries + extras) to JSONL."""
        if self.registry is None:
            raise RuntimeError("cannot snapshot disabled telemetry")
        merged = {"requests": self.requests.summary()}
        if extra:
            merged.update(extra)
        return self.registry.write_jsonl(path, merged)


def make_telemetry(enabled: bool, *, trace_capacity: int = 65536,
                   clock=None) -> Telemetry:
    """`Telemetry.make` or the shared-nothing disabled handle."""
    return (Telemetry.make(trace_capacity=trace_capacity, clock=clock)
            if enabled else Telemetry.disabled())
