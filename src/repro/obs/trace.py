"""Span/event recorder exporting Chrome/Perfetto trace-event JSON.

The paper's argument is a bandwidth-utilization *timeline* — rewrite and
compute activity laid out against wall time so the flat-traffic property is
visible, not just summarized.  This module is that timeline for the repo:
a `TraceRecorder` collects events into a bounded ring buffer and exports
them in the Chrome trace-event format (``{"traceEvents": [...]}``), which
Perfetto (https://ui.perfetto.dev) and ``chrome://tracing`` both load
directly.

Design constraints, in order:

  * near-zero overhead when disabled — `NULL_TRACE` is a method-compatible
    singleton whose every call is a constant-time no-op, so instrumentation
    sites cost one attribute check;
  * bounded memory — a ring buffer of `capacity` events; once full the
    OLDEST event is dropped and `dropped` counts it (a long serving run
    keeps the most recent window, and the drop count says how much history
    fell off the back);
  * explicit clock injection — every timestamp flows through the `clock`
    callable (seconds, `time.perf_counter` by default), so tests drive a
    fake clock and get deterministic traces.

Event vocabulary (Chrome trace-event phases used):

  ``X``   complete event: a span with `ts` + `dur` (`complete`, `span`)
  ``i``   instant event (`instant`)
  ``C``   counter track (`counter`)
  ``b``/``e``  async span keyed by `id` — request lifecycles that overlap
          arbitrarily across lanes (`async_begin` / `async_end`)
  ``M``   metadata: process/thread names for the Perfetto track labels

All timestamps in the export are microseconds (the trace-event unit).
"""
from __future__ import annotations

import json
import time
from collections import deque
from contextlib import contextmanager


class TraceRecorder:
    """Bounded ring-buffer trace-event recorder (see module docstring)."""

    enabled = True

    def __init__(self, capacity: int = 65536, clock=None):
        if capacity < 1:
            raise ValueError("capacity >= 1")
        self.capacity = capacity
        self.clock = clock or time.perf_counter
        self._events: "deque[dict]" = deque()
        self._meta: "list[dict]" = []     # M events: never dropped, tiny
        self.dropped = 0

    # ------------------------------------------------------------- core
    def now_us(self) -> float:
        """Current clock reading in trace-event microseconds."""
        return self.clock() * 1e6

    def emit(self, event: dict) -> None:
        """Append one raw trace-event dict, honoring the ring capacity."""
        if len(self._events) >= self.capacity:
            self._events.popleft()
            self.dropped += 1
        self._events.append(event)

    def __len__(self) -> int:
        return len(self._events)

    @property
    def events(self) -> "tuple[dict, ...]":
        return tuple(self._events)

    def clear(self) -> None:
        self._events.clear()
        self.dropped = 0

    # ---------------------------------------------------------- emitters
    def complete(self, name: str, ts_us: float, dur_us: float, *,
                 pid: int = 0, tid: int = 0, args: "dict | None" = None,
                 cat: str = "span") -> None:
        ev = {"name": name, "ph": "X", "ts": ts_us, "dur": max(0.0, dur_us),
              "pid": pid, "tid": tid, "cat": cat}
        if args:
            ev["args"] = args
        self.emit(ev)

    def instant(self, name: str, *, ts_us: "float | None" = None,
                pid: int = 0, tid: int = 0, args: "dict | None" = None,
                cat: str = "event") -> None:
        ev = {"name": name, "ph": "i", "s": "t",
              "ts": self.now_us() if ts_us is None else ts_us,
              "pid": pid, "tid": tid, "cat": cat}
        if args:
            ev["args"] = args
        self.emit(ev)

    def counter(self, name: str, values: dict, *,
                ts_us: "float | None" = None, pid: int = 0) -> None:
        self.emit({"name": name, "ph": "C",
                   "ts": self.now_us() if ts_us is None else ts_us,
                   "pid": pid, "tid": 0, "args": dict(values)})

    def async_begin(self, name: str, aid: int, *,
                    ts_us: "float | None" = None, pid: int = 0,
                    args: "dict | None" = None, cat: str = "request") -> None:
        ev = {"name": name, "ph": "b", "id": aid, "cat": cat,
              "ts": self.now_us() if ts_us is None else ts_us,
              "pid": pid, "tid": 0}
        if args:
            ev["args"] = args
        self.emit(ev)

    def async_end(self, name: str, aid: int, *,
                  ts_us: "float | None" = None, pid: int = 0,
                  args: "dict | None" = None, cat: str = "request") -> None:
        ev = {"name": name, "ph": "e", "id": aid, "cat": cat,
              "ts": self.now_us() if ts_us is None else ts_us,
              "pid": pid, "tid": 0}
        if args:
            ev["args"] = args
        self.emit(ev)

    @contextmanager
    def span(self, name: str, *, pid: int = 0, tid: int = 0,
             args: "dict | None" = None, cat: str = "span"):
        """Measure a with-block on the injected clock, emit one X event."""
        t0 = self.now_us()
        try:
            yield self
        finally:
            self.complete(name, t0, self.now_us() - t0,
                          pid=pid, tid=tid, args=args, cat=cat)

    # ---------------------------------------------------------- metadata
    def name_process(self, pid: int, name: str) -> None:
        self._meta.append({"name": "process_name", "ph": "M", "pid": pid,
                           "tid": 0, "args": {"name": name}})

    def name_thread(self, pid: int, tid: int, name: str) -> None:
        self._meta.append({"name": "thread_name", "ph": "M", "pid": pid,
                           "tid": tid, "args": {"name": name}})

    # ------------------------------------------------------------ export
    def to_chrome(self) -> dict:
        """Chrome/Perfetto trace-event JSON object (load it directly)."""
        return {
            "traceEvents": self._meta + list(self._events),
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped,
                          "capacity": self.capacity},
        }

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
            f.write("\n")


class _NullTrace:
    """Disabled-path stand-in: every method is a no-op; instrumentation
    sites gate heavier work (clock reads, arg dict construction) on
    `trace.enabled` and fall through to these for anything else."""

    enabled = False
    dropped = 0
    capacity = 0
    events: "tuple[dict, ...]" = ()

    def now_us(self) -> float:
        return 0.0

    def __len__(self) -> int:
        return 0

    def emit(self, event) -> None:
        pass

    def complete(self, *a, **k) -> None:
        pass

    def instant(self, *a, **k) -> None:
        pass

    def counter(self, *a, **k) -> None:
        pass

    def async_begin(self, *a, **k) -> None:
        pass

    def async_end(self, *a, **k) -> None:
        pass

    @contextmanager
    def span(self, *a, **k):
        yield self

    def name_process(self, *a, **k) -> None:
        pass

    def name_thread(self, *a, **k) -> None:
        pass

    def clear(self) -> None:
        pass

    def to_chrome(self) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        raise RuntimeError("cannot write a disabled trace (NULL_TRACE)")


NULL_TRACE = _NullTrace()

# Fixed pid layout for the serving instrumentation so every exported trace
# lands request/engine/kernel activity on the same named tracks.
PID_SERVING = 1    # engine steps (tid 0) + one tid per lane (TID_LANE0 + i)
PID_REQUESTS = 2   # async request-lifecycle spans keyed by rid
PID_KERNEL = 3     # chunk-issue schedule lanes: tid 0 = DMA, tid 1 = compute
TID_ENGINE = 0
TID_LANE0 = 10
TID_DMA = 0
TID_COMPUTE = 1


def annotate_serving_tracks(trace: "TraceRecorder", slots: int) -> None:
    """Name the fixed serving/kernel tracks on a fresh recorder."""
    if not trace.enabled:
        return
    trace.name_process(PID_SERVING, "serving engine")
    trace.name_thread(PID_SERVING, TID_ENGINE, "steps")
    for lane in range(slots):
        trace.name_thread(PID_SERVING, TID_LANE0 + lane, f"lane {lane}")
    trace.name_process(PID_REQUESTS, "requests")
    trace.name_process(PID_KERNEL, "kernel chunk schedule")
    trace.name_thread(PID_KERNEL, TID_DMA, "DMA lane (HBM->VMEM)")
    trace.name_thread(PID_KERNEL, TID_COMPUTE, "compute lane")
