"""Typed per-step, per-component HBM-byte ledger for the serving engines.

The paper's whole argument is a bandwidth-utilization ledger: distribute
off-chip link activity evenly across time and nothing starves.  Before this
module, the repo's byte accounting was scattered — hand-maintained dict
literals in `serving/engine.py`, schema-parity zero dicts hand-synced in
`serving/dense_engine.py`, ad-hoc tallies in `benchmarks/run.py`.  The
ledger is the single typed source of truth:

  * `STEP_SCHEMA` is THE per-step metrics schema.  Both engines emit rows
    through `BandwidthLedger.record`, which zero-fills missing fields,
    derives the composites, and rejects unknown keys — schema drift between
    the engines is now a constructor error, not a silently diverging dict.
  * Byte components per step: `param_bytes` (the weight stream — the
    paper's "rewrite" traffic), `kv_write_bytes` / `kv_read_bytes` (KV
    traffic proportional to processed/visible tokens), the two
    attention-read paths (`attn_bytes_gather` materialized vs
    `attn_bytes_stream` DMA'd by the Pallas ring), plus the two savings
    columns: `prefix_saved_bytes` (KV writes the radix cache skipped) and
    `spec_saved_bytes` (weight streams amortized by accepted draft tokens).
  * The composite `hbm_bytes = param_bytes + kv_write_bytes + kv_read_bytes`
    reproduces the previous hand-built projection exactly (regression-
    tested in tests/test_obs.py), so the BENCH_serving.json trajectory
    stays comparable across PRs.

Memory is bounded: `retention > 0` keeps only the most recent N rows as a
ring; evicted rows fold into a running `rollup` (summed numeric fields +
step count), and `totals()` always covers the engine's full lifetime —
long serving runs stop growing per step while aggregate byte accounting
stays exact.  `retention == 0` (the default) retains everything, which is
what the existing tests/benchmarks slice into.

`utilization_report()` is the paper-facing column: the measured
distribution of per-step link activity (mean/peak of `hbm_bytes`, plus its
CoV — 1.0 means perfectly flat, the GPP ideal) next to the utilization the
cycle-accurate GPP simulator (`core.simulator.simulate_gpp`) predicts for
a matched rewrite:compute ratio.
"""
from __future__ import annotations

import statistics
from collections import deque

# THE per-step metrics schema, shared by ServingEngine and
# DenseServingEngine (tests/test_obs.py asserts both emit exactly this).
STEP_SCHEMA: "tuple[str, ...]" = (
    # step composition (token counts)
    "step", "tokens", "prefill_tokens", "prefill_real_tokens",
    "decode_tokens", "verify_tokens", "drafted_tokens", "accepted_tokens",
    "acceptance_rate",
    # pool / queue state
    "blocks_in_use", "free_blocks", "queue_depth", "preempted",
    "prefix_hit_tokens", "blocks_shared",
    # HBM byte components (the ledger proper)
    "param_bytes", "kv_write_bytes", "kv_read_bytes",
    "prefix_saved_bytes", "spec_saved_bytes",
    "hbm_bytes", "attn_bytes_gather", "attn_bytes_stream",
    # wall time of the step (us; 0.0 when telemetry is disabled)
    "step_wall_us",
)

_SCHEMA_SET = frozenset(STEP_SCHEMA)


def step_row(**fields) -> dict:
    """One schema-complete step row: zero-fill, derive, reject unknowns.

    Derived when not explicitly passed:
      acceptance_rate = accepted/drafted (0 when nothing drafted)
      hbm_bytes       = param_bytes + kv_write_bytes + kv_read_bytes
      spec_saved_bytes = accepted_tokens * param_bytes (each accepted draft
                         token is one decode step's weight stream avoided)
    """
    unknown = set(fields) - _SCHEMA_SET
    if unknown:
        raise ValueError(f"unknown step-metric fields: {sorted(unknown)} "
                         f"(schema: {STEP_SCHEMA})")
    row = {k: 0 for k in STEP_SCHEMA}
    row["acceptance_rate"] = 0.0
    row["step_wall_us"] = 0.0
    row.update(fields)
    if "acceptance_rate" not in fields and row["drafted_tokens"]:
        row["acceptance_rate"] = row["accepted_tokens"] / row["drafted_tokens"]
    if "hbm_bytes" not in fields:
        row["hbm_bytes"] = (row["param_bytes"] + row["kv_write_bytes"]
                            + row["kv_read_bytes"])
    if "spec_saved_bytes" not in fields:
        row["spec_saved_bytes"] = row["accepted_tokens"] * row["param_bytes"]
    return row


class BandwidthLedger:
    """List-compatible bounded step-metrics store (see module docstring).

    Supports the access patterns the existing tests/benchmarks use on the
    old plain list — truthiness, len, iteration, int/slice indexing — so
    `engine.metrics` keeps its contract while gaining typed rows, bounded
    retention, and lifetime totals.
    """

    SCHEMA = STEP_SCHEMA

    def __init__(self, retention: int = 0):
        if retention < 0:
            raise ValueError("retention >= 0 (0 = unbounded)")
        self.retention = retention
        self._rows: "deque[dict]" = deque()
        self.rollup: "dict[str, float]" = {}   # sums over EVICTED rows
        self.rolled_up_steps = 0
        self.steps = 0                         # lifetime row count

    # ------------------------------------------------------------ record
    def record(self, **fields) -> dict:
        row = step_row(step=self.steps, **fields)
        self.steps += 1
        self._rows.append(row)
        if self.retention and len(self._rows) > self.retention:
            evicted = self._rows.popleft()
            for k, v in evicted.items():
                if k != "step":
                    self.rollup[k] = self.rollup.get(k, 0) + v
            self.rolled_up_steps += 1
        return row

    def append(self, row: dict) -> None:
        """Accept a pre-built row (must already be schema-complete)."""
        missing = _SCHEMA_SET - set(row)
        if missing:
            raise ValueError(f"row missing schema fields: {sorted(missing)}")
        self.record(**{k: row[k] for k in row if k != "step"})

    # --------------------------------------------------- list compatibility
    def __len__(self) -> int:
        return len(self._rows)

    def __bool__(self) -> bool:
        return bool(self._rows)

    def __iter__(self):
        return iter(self._rows)

    def __getitem__(self, idx):
        if isinstance(idx, slice):
            return list(self._rows)[idx]
        return self._rows[idx]

    # ------------------------------------------------------------- sums
    def total(self, key: str) -> float:
        """Lifetime sum of a numeric field: retained rows + rollup."""
        if key not in _SCHEMA_SET:
            raise KeyError(key)
        return self.rollup.get(key, 0) + sum(r[key] for r in self._rows)

    def totals(self) -> dict:
        return {k: self.total(k) for k in STEP_SCHEMA if k != "step"}

    # ------------------------------------------------------ paper column
    def utilization_report(self, *, sim_macros: int = 32,
                           sim_rounds: int = 8) -> dict:
        """Measured vs simulate_gpp-predicted link-utilization summary.

        measured_bw_utilization  mean/peak of per-step hbm_bytes over the
                                 retained window — 1.0 means every step
                                 moves the same bytes (perfectly flat, the
                                 GPP ideal); prefill bursts push it down.
        hbm_bytes_per_step_cov   the same flatness as a CoV (0 = flat).
        predicted_bw_utilization bus-busy fraction of the cycle-accurate
                                 GPP simulator at a rewrite:compute ratio
                                 matched to the measured step composition
                                 (weight-stream bytes : total step bytes).
        """
        hbm = [float(r["hbm_bytes"]) for r in self._rows]
        if not hbm or max(hbm) <= 0:
            return {"measured_bw_utilization": 0.0,
                    "predicted_bw_utilization": 0.0,
                    "hbm_bytes_per_step_cov": 0.0,
                    "steps_measured": len(hbm)}
        measured = (sum(hbm) / len(hbm)) / max(hbm)
        mean = sum(hbm) / len(hbm)
        cov = statistics.pstdev(hbm) / mean if mean else 0.0

        from repro.core.analytical import PimConfig
        from repro.core.simulator import simulate_gpp

        # map the measured step composition onto the paper's knobs: the
        # weight stream is the "rewrite", everything else the compute-side
        # traffic; t_pim/t_rw = ratio  =>  n_in = ratio * size_ou / s.
        params = self.total("param_bytes") / self.steps if self.steps else 0
        ratio = max(0.125, (mean - params) / params) if params else 1.0
        cfg = PimConfig()
        cfg = cfg.with_(n_in=max(1.0, ratio * cfg.size_ou / cfg.s))
        sim = simulate_gpp(cfg, sim_macros, sim_rounds)
        predicted = (sim.bw_busy_cycles / sim.total_cycles
                     if sim.total_cycles else 0.0)
        return {"measured_bw_utilization": measured,
                "predicted_bw_utilization": predicted,
                "hbm_bytes_per_step_cov": cov,
                "steps_measured": len(hbm)}

    def summary(self) -> dict:
        """Aggregate export unit for metrics snapshots."""
        out = {"steps": self.steps,
               "rolled_up_steps": self.rolled_up_steps,
               "retention": self.retention}
        out.update({f"total_{k}": self.total(k) for k in (
            "tokens", "prefill_tokens", "decode_tokens", "verify_tokens",
            "drafted_tokens", "accepted_tokens", "prefix_hit_tokens",
            "param_bytes", "kv_write_bytes", "kv_read_bytes",
            "prefix_saved_bytes", "spec_saved_bytes", "hbm_bytes",
            "attn_bytes_gather", "attn_bytes_stream")})
        out.update(self.utilization_report())
        return out
