"""Closed-form analytic model of the three write/compute schedules (paper Eqs 1-9).

All times are in clock cycles, all sizes in bytes, bandwidths in bytes/cycle.
The model is exact for fractional macro counts ("theory" column of Table II);
`repro.core.simulator` provides the integer-macro cycle-accurate counterpart
("practice" column).

Parameter glossary (paper Table I):
    band        off-chip bandwidth                      [B/cycle]
    size_macro  macro (weight tile) size                [B]
    size_ou     operation-unit size: bytes of weights consumed per cycle
                while computing one input vector        [B/cycle]
    s           rewrite speed per macro                 [B/cycle]
    n_in        input vectors per compute phase         [-]
"""
from __future__ import annotations

import dataclasses
import math

STRATEGIES = ("insitu", "naive_pp", "gpp")


@dataclasses.dataclass(frozen=True)
class PimConfig:
    """Hardware/workload point for the analytic model."""

    size_macro: float = 32 * 32  # bytes (paper: 32x32 B)
    size_ou: float = 4 * 8       # bytes/cycle (paper: 4x8 B)
    s: float = 4.0               # rewrite speed, bytes/cycle/macro
    n_in: float = 8.0            # input vectors per compute phase
    band: float = 128.0          # off-chip bandwidth, bytes/cycle

    @property
    def time_rewrite(self) -> float:
        """t_rw = size_macro / s   (cycles to fully rewrite one macro)."""
        return self.size_macro / self.s

    @property
    def time_pim(self) -> float:
        """t_pim = size_macro * n_in / size_ou  (cycles of one compute phase)."""
        return self.size_macro * self.n_in / self.size_ou

    @property
    def ratio(self) -> float:
        """t_pim / t_rw."""
        return self.time_pim / self.time_rewrite

    def with_(self, **kw) -> "PimConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Eqs 1-2: naive ping-pong macro utilization
# ---------------------------------------------------------------------------

def naive_pp_macro_util(cfg: PimConfig) -> float:
    """Macro utilization of naive ping-pong (paper Eqs 1-2).

    util = (t_pim + t_rw) / (2 * max(t_pim, t_rw)); peaks at 1.0 only when
    t_pim == t_rw.
    """
    tp, tr = cfg.time_pim, cfg.time_rewrite
    return (tp + tr) / (2.0 * max(tp, tr))


def insitu_macro_util(cfg: PimConfig) -> float:
    """In-situ write/compute: macros always busy (write or compute) but the
    paper counts a macro "active" only while computing; utilization in the
    busy-fraction sense used for Fig 7(d) is t_pim/(t_pim+t_rw)."""
    tp, tr = cfg.time_pim, cfg.time_rewrite
    return tp / (tp + tr)


def gpp_macro_util(cfg: PimConfig) -> float:
    """Generalized ping-pong never idles a macro."""
    return 1.0


# ---------------------------------------------------------------------------
# Eqs 3-4: macros supportable at fixed off-chip bandwidth (full usage)
# ---------------------------------------------------------------------------

def num_macros(cfg: PimConfig, strategy: str) -> float:
    """Number of macros a bandwidth `band` sustains at full utilization.

    Eq 3:  in-situ  -> band/s        (all macros rewrite simultaneously)
           naive_pp -> 2*band/s      (only half rewrite at a time)
    Eq 4:  gpp      -> (t_pim+t_rw)*band/(t_rw*s)
                       (each macro's average demand is t_rw*s/(t_pim+t_rw))
    """
    tp, tr = cfg.time_pim, cfg.time_rewrite
    if strategy == "insitu":
        return cfg.band / cfg.s
    if strategy == "naive_pp":
        return 2.0 * cfg.band / cfg.s
    if strategy == "gpp":
        return (tp + tr) * cfg.band / (tr * cfg.s)
    raise ValueError(f"unknown strategy {strategy!r}")


def per_macro_bandwidth(cfg: PimConfig, strategy: str) -> float:
    """Average off-chip bandwidth demand of one macro [B/cycle]."""
    tp, tr = cfg.time_pim, cfg.time_rewrite
    if strategy == "insitu":
        return cfg.s                      # bursty: s while rewriting, all together
    if strategy == "naive_pp":
        return cfg.s / 2.0                # two groups alternate
    if strategy == "gpp":
        return tr * cfg.s / (tp + tr)     # flattened to the true average
    raise ValueError(f"unknown strategy {strategy!r}")


def naive_pp_perf_factor(cfg: PimConfig) -> float:
    """Per-macro throughput retention of naive ping-pong vs an ideal macro
    (paper: (t_pim+t_rw)/(t_pim+t_rw+|t_pim-t_rw|))."""
    tp, tr = cfg.time_pim, cfg.time_rewrite
    return (tp + tr) / (tp + tr + abs(tp - tr))


# ---------------------------------------------------------------------------
# Eqs 5-6: design-phase ratios at equal off-chip bandwidth
# ---------------------------------------------------------------------------

def macro_count_ratio(cfg: PimConfig) -> tuple[float, float, float]:
    """Eq 5 — macros used by (gpp, insitu, naive_pp) normalized to insitu=1.

    gpp : insitu : naive = (size_macro*n_in/size_ou + size_macro/s)
                           / (size_macro/s)  :  1  :  2
    """
    tp, tr = cfg.time_pim, cfg.time_rewrite
    return ((tp + tr) / tr, 1.0, 2.0)


def execution_time_ratio(cfg: PimConfig) -> tuple[float, float, float]:
    """Eq 6 — execution time of (gpp, insitu, naive_pp) for a fixed workload
    with each strategy sized per Eqs 3-4, normalized to t_gpp = 1.

    NOTE: the paper labels Eq 6 an "execution time ratio" but the printed
    expression is dimensionally a *throughput* ratio — only that reading makes
    gpp == naive at t_pim == t_rw and gpp 2x in-situ, as §IV-B states and our
    DES confirms.  First-principles times (derived from Eq 3-4 macro counts
    and per-round periods, validated by `simulator.py`):

        t_gpp    ∝ t_rw                 (bus saturated, 100% macro util)
        t_insitu ∝ t_pim + t_rw
        t_naive  ∝ max(t_pim, t_rw)

    i.e. 1 : (n_in*s+size_ou)/size_ou
           : (n_in*s+size_ou+|n_in*s-size_ou|)/(2*size_ou)
    — the reciprocal of the paper's printed right-hand term, matching its
    worked examples.
    """
    nin_s = cfg.n_in * cfg.s
    ou = cfg.size_ou
    t_gpp = 1.0
    t_insitu = (nin_s + ou) / ou
    t_naive = (nin_s + ou + abs(nin_s - ou)) / (2.0 * ou)
    return (t_gpp, t_insitu, t_naive)


def throughput_per_band(cfg: PimConfig, strategy: str) -> float:
    """Aggregate useful compute throughput (weight-bytes*inputs processed per
    cycle, i.e. size_ou-equivalents) sustained by `band`, combining the macro
    count (Eqs 3-4) with the per-macro retention factor.

    This is the quantity behind Fig 6(a): execution latency of a fixed
    workload is workload / throughput.
    """
    n = num_macros(cfg, strategy)
    per_macro = cfg.size_ou  # bytes of weights consumed per cycle while computing
    if strategy == "insitu":
        duty = cfg.time_pim / (cfg.time_pim + cfg.time_rewrite)
        return n * per_macro * duty
    if strategy == "naive_pp":
        return n * per_macro * naive_pp_perf_factor(cfg) * (
            cfg.time_pim / (cfg.time_pim + cfg.time_rewrite)
        ) * 2.0
    if strategy == "gpp":
        duty = cfg.time_pim / (cfg.time_pim + cfg.time_rewrite)
        return n * per_macro * duty
    raise ValueError(f"unknown strategy {strategy!r}")


# ---------------------------------------------------------------------------
# Eqs 7-9: runtime-phase bandwidth-reduction adaptation
# ---------------------------------------------------------------------------

def insitu_perf_degradation(cfg: PimConfig, n: float) -> float:
    """Eq 7 — in-situ: keep all macros, slow the rewrite by n.

    remaining perf = (t_pim + t_rw) / (t_pim + n*t_rw).
    """
    tp, tr = cfg.time_pim, cfg.time_rewrite
    return (tp + tr) / (tp + n * tr)


def naive_pp_perf_degradation(cfg: PimConfig, n: float) -> float:
    """Eq 8 — naive ping-pong under band/n.

    While t_pim > t_rw*n' the slowdown only eats idle time (perf flat); once
    rewrite dominates, performance falls as 1/n relative to the t_pim==t_rw
    point.  Design-phase anchor in the paper is t_pim == t_rw, so degradation
    is simply 1/n from there; we implement the general form.
    """
    tp, tr = cfg.time_pim, cfg.time_rewrite
    eff_tr = n * tr
    if eff_tr <= tp:
        # still hidden by compute; each macro pair alternates perfectly.
        return 1.0
    # rewrite dominates: throughput ∝ 1/eff_tr; normalize to the undegraded
    # naive-pp throughput (∝ 1/max(tp, tr)).
    return max(tp, tr) / eff_tr


def gpp_perf_degradation(cfg: PimConfig, n: float) -> float:
    """Eq 9 — generalized ping-pong under band/n.

    GPP reduces active macros to num/m and lets each survivor use m× the
    on-chip buffer => n_in' = m*n_in => t_pim' = m*t_pim.  m solves
        (t_rw*s/(t_pim' + t_rw)) * num/m = band/n
    which is a quadratic in m; perf retention is (throughput')/(throughput) =
    (num/m * 1) / num = 1/m ... but each macro also computes the same rate, so
    retention = 1/m with m from Eq 9:

        perf = 2*(n_in*s + size_ou) /
               (size_ou + sqrt(size_ou^2 + 4*num*size_ou*n_in*s^2*n / band))

    (paper Eq 9, with num = num_macro at design point).
    """
    num = num_macros(cfg, "gpp")
    ou, s, nin, band = cfg.size_ou, cfg.s, cfg.n_in, cfg.band
    denom = ou + math.sqrt(ou * ou + 4.0 * num * ou * nin * s * s * n / band)
    return 2.0 * (nin * s + ou) / denom


def gpp_adapted_point(cfg: PimConfig, n: float) -> PimConfig:
    """Return the adapted operating point (fewer macros, larger n_in) GPP
    chooses when bandwidth drops to band/n.  Solves for m such that the
    surviving num/m macros exactly saturate band/n."""
    perf = gpp_perf_degradation(cfg, n)
    m = 1.0 / perf
    return cfg.with_(n_in=cfg.n_in * m, band=cfg.band / n)
