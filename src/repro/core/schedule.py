"""Schedule IR and builders for the three concurrent write/compute strategies.

A schedule is a list of `ScheduleOp`s — (macro, kind, start, dur, bytes) — the
TPU-idiomatic equivalent of the paper's PUMA-derived assembly: the simulator
executes it, tests assert its properties (flat bandwidth, zero idle), and the
JAX streamer (`core/streamer.py`) consumes the same planner to set its ring
depth / chunking.

Builders are *idealized* (no bandwidth arbiter): they place ops where the
strategy intends them.  `repro.core.simulator` plays the same strategies
against a real shared-bus arbiter and reports what actually happens.
"""
from __future__ import annotations

import dataclasses
import math
import statistics
from typing import Iterator

from repro.core.analytical import PimConfig

KIND_REWRITE = "rewrite"
KIND_COMPUTE = "compute"


@dataclasses.dataclass(frozen=True)
class ScheduleOp:
    macro: int
    kind: str          # "rewrite" | "compute"
    start: float       # cycles
    dur: float         # cycles
    nbytes: float      # off-chip bytes moved (0 for compute)

    @property
    def end(self) -> float:
        return self.start + self.dur


@dataclasses.dataclass(frozen=True)
class Schedule:
    ops: tuple[ScheduleOp, ...]
    num_macros: int
    cfg: PimConfig
    strategy: str

    @property
    def makespan(self) -> float:
        return max((op.end for op in self.ops), default=0.0)

    def bandwidth_profile(self, resolution: int = 2048) -> "list[float]":
        """Off-chip bandwidth demand sampled over the makespan [B/cycle]."""
        span = self.makespan
        if span <= 0:
            return []
        out = [0.0] * resolution
        dt = span / resolution
        for op in self.ops:
            if op.kind != KIND_REWRITE or op.dur <= 0:
                continue
            rate = op.nbytes / op.dur
            i0 = int(op.start / dt)
            i1 = min(resolution - 1, int((op.end - 1e-9) / dt))
            for i in range(i0, i1 + 1):
                lo = max(op.start, i * dt)
                hi = min(op.end, (i + 1) * dt)
                out[i] += rate * max(0.0, hi - lo) / dt
        return out

    def peak_bandwidth(self) -> float:
        """Exact peak instantaneous bandwidth demand [B/cycle]."""
        events: list[tuple[float, float]] = []
        for op in self.ops:
            if op.kind != KIND_REWRITE or op.dur <= 0:
                continue
            rate = op.nbytes / op.dur
            events.append((op.start, rate))
            events.append((op.end, -rate))
        events.sort()
        cur = peak = 0.0
        for _, delta in events:
            cur += delta
            peak = max(peak, cur)
        return peak

    def avg_bandwidth(self) -> float:
        total = sum(op.nbytes for op in self.ops if op.kind == KIND_REWRITE)
        return total / self.makespan if self.makespan else 0.0

    def bandwidth_idle_fraction(self) -> float:
        """Fraction of the makespan with zero rewrite traffic in flight."""
        span = self.makespan
        if span <= 0:
            return 0.0
        ivals = sorted(
            (op.start, op.end) for op in self.ops if op.kind == KIND_REWRITE
        )
        busy = 0.0
        cur_s = cur_e = None
        for s, e in ivals:
            if cur_s is None:
                cur_s, cur_e = s, e
            elif s <= cur_e:
                cur_e = max(cur_e, e)
            else:
                busy += cur_e - cur_s
                cur_s, cur_e = s, e
        if cur_s is not None:
            busy += cur_e - cur_s
        return 1.0 - busy / span

    def macro_utilization(self) -> float:
        """Mean fraction of the makespan each macro spends busy (either op)."""
        span = self.makespan
        if span <= 0:
            return 0.0
        busy = sum(op.dur for op in self.ops)
        return busy / (span * self.num_macros)


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------

def gpp_group_count(cfg: PimConfig) -> int:
    """Number of stagger groups G = round((t_pim + t_rw) / t_rw), >= 2.

    With G groups, group k starts its rewrite at k*(t_pim+t_rw)/G; exactly
    num/G macros rewrite at any instant when the ratio divides evenly.
    """
    tp, tr = cfg.time_pim, cfg.time_rewrite
    return max(2, round((tp + tr) / tr))


def gpp_concurrent_rewriters(cfg: PimConfig, num_macros: int) -> float:
    """Average number of simultaneously-rewriting macros under GPP."""
    tp, tr = cfg.time_pim, cfg.time_rewrite
    return num_macros * tr / (tp + tr)


def build_insitu(cfg: PimConfig, num_macros: int, rounds: int) -> Schedule:
    """All macros rewrite together, then all compute together."""
    tp, tr = cfg.time_pim, cfg.time_rewrite
    ops = []
    for r in range(rounds):
        t0 = r * (tp + tr)
        for m in range(num_macros):
            ops.append(ScheduleOp(m, KIND_REWRITE, t0, tr, cfg.size_macro))
            ops.append(ScheduleOp(m, KIND_COMPUTE, t0 + tr, tp, 0.0))
    return Schedule(tuple(ops), num_macros, cfg, "insitu")


def build_naive_pp(cfg: PimConfig, num_macros: int, rounds: int) -> Schedule:
    """Two synchronized banks: one computes GeMM n while the other rewrites
    weights for GeMM n+1; banks swap when BOTH finish (paper Fig 3b)."""
    tp, tr = cfg.time_pim, cfg.time_rewrite
    period = max(tp, tr)
    half = num_macros // 2
    bank = [0] * half + [1] * (num_macros - half)
    ops = []
    # phase p: bank (p % 2) computes round p, bank ((p+1) % 2) rewrites
    # weights for round p+1.  Warm-up: bank0 rewrites round 0 first.
    for m in range(num_macros):
        if bank[m] == 0:
            ops.append(ScheduleOp(m, KIND_REWRITE, 0.0, tr, cfg.size_macro))
    t0 = tr  # steady phases start after warm-up fill
    for p in range(rounds):
        comp_bank = p % 2
        for m in range(num_macros):
            if bank[m] == comp_bank:
                ops.append(ScheduleOp(m, KIND_COMPUTE, t0, tp, 0.0))
            elif p + 1 < rounds:
                ops.append(ScheduleOp(m, KIND_REWRITE, t0, tr, cfg.size_macro))
        t0 += period
    return Schedule(tuple(ops), num_macros, cfg, "naive_pp")


def build_gpp(cfg: PimConfig, num_macros: int, rounds: int) -> Schedule:
    """Generalized ping-pong: macro groups stagger rewrite starts so that
    off-chip traffic is flat and no macro ever idles (paper Fig 3c)."""
    tp, tr = cfg.time_pim, cfg.time_rewrite
    period = tp + tr
    groups = gpp_group_count(cfg)
    ops = []
    for m in range(num_macros):
        g = m % groups
        offset = g * period / groups
        for r in range(rounds):
            t0 = offset + r * period
            ops.append(ScheduleOp(m, KIND_REWRITE, t0, tr, cfg.size_macro))
            ops.append(ScheduleOp(m, KIND_COMPUTE, t0 + tr, tp, 0.0))
    return Schedule(tuple(ops), num_macros, cfg, "gpp")


def build(strategy: str, cfg: PimConfig, num_macros: int, rounds: int) -> Schedule:
    return {
        "insitu": build_insitu,
        "naive_pp": build_naive_pp,
        "gpp": build_gpp,
    }[strategy](cfg, num_macros, rounds)


# ---------------------------------------------------------------------------
# Planner interface consumed by the JAX streamer (core/streamer.py)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StreamPlan:
    """GPP plan for streaming L weight blocks through a compute pipeline.

    ring_depth   number of weight buffers held concurrently (paper: groups
                 rewriting + the one computing).
    chunks       chunks each block's transfer is split into, issued one per
                 compute slot, so link demand is flat.
    t_compute    per-block compute time estimate [s]
    t_transfer   per-block transfer time estimate [s]
    """

    ring_depth: int
    chunks: int
    t_compute: float
    t_transfer: float

    @property
    def ratio(self) -> float:
        return self.t_compute / self.t_transfer if self.t_transfer else math.inf


def plan_stream(
    *,
    block_bytes: float,
    compute_flops: float,
    flops_per_s: float,
    transfer_bytes_per_s: float,
    max_ring: int = 8,
) -> StreamPlan:
    """Plan ring depth & chunking for streaming weight blocks.

    TPU mapping of Eq 4: a block is a "macro", transfer is the "rewrite",
    the per-block matmul is the "compute".  ring = ceil(t_tr/t_cmp)+1 buffers
    keep compute from ever waiting; `chunks` splits each transfer so each
    compute slot carries ~1/ratio of a block (flat bandwidth).
    """
    t_cmp = compute_flops / flops_per_s
    t_tr = block_bytes / transfer_bytes_per_s
    if t_cmp <= 0:
        return StreamPlan(2, 1, t_cmp, t_tr)
    ring = min(max_ring, max(2, math.ceil(t_tr / t_cmp) + 1))
    chunks = max(1, round(t_cmp / t_tr)) if t_tr > 0 else 1
    return StreamPlan(ring, chunks, t_cmp, t_tr)


# ---------------------------------------------------------------------------
# Serving chunk planner: GPP flatness applied to prefill
# ---------------------------------------------------------------------------

def plan_serve_chunk(*, token_budget: int, decode_lanes: int,
                     block_size: int, cached_tokens: int = 0) -> int:
    """Prefill chunk size for the paged serving engine (serving/scheduler.py).

    Same math as `plan_stream`'s chunking, one level up: a prompt's prefill
    is the bursty "rewrite" (its KV-write and weight-read traffic), decode
    steps are the compute slots, and the flat-bandwidth condition is that
    every step moves the same token count.  A step carries up to
    `decode_lanes` decode tokens plus one prefill chunk, so the chunk is the
    largest KV-block multiple that keeps the step at or under the flat
    `token_budget` target — the per-step analogue of "each compute slot
    carries ~1/ratio of a block".

    cached_tokens: expected per-admission prefix-cache hit depth
    (serving/prefix.py).  Cached tokens enter a request's context without
    compute or HBM writes — a prompt's real prefill burst shrinks by that
    much, so the budget they would have burned is handed back to the chunk:
    a deployment with a known steady hit depth can carry a larger chunk at
    the same real per-step traffic, finishing cold prompts sooner without
    un-flattening the stream.
    """
    if block_size < 1:
        raise ValueError("block_size >= 1")
    if decode_lanes < 0:
        raise ValueError("decode_lanes >= 0")
    if cached_tokens < 0:
        raise ValueError("cached_tokens >= 0")
    spare = max(block_size, token_budget + cached_tokens - decode_lanes)
    return max(block_size, (spare // block_size) * block_size)


def plan_verify_budget(*, token_budget: int, prefill_tokens: int,
                       decode_lanes: int) -> int:
    """Draft tokens a speculative-verify step may add on top of the step's
    prefill chunk and decode lanes — the GPP flatness math extended to
    accepted-token bursts.

    The flat target is `token_budget` tokens per step.  A prefill chunk
    plus the decode lanes already claim `prefill_tokens + decode_lanes` of
    it; the SLACK is what drafting may fill.  On prefill-carrying steps
    the slack is ~0 (the chunk was sized to reach the budget), so drafts
    ride the decode-only steps that would otherwise under-fill the link —
    per-step token count (and hence weight-stream amortization) stays flat
    instead of decode trickling one token per lane per weight pass.
    """
    if token_budget < 0:
        raise ValueError("token_budget >= 0")
    if prefill_tokens < 0:
        raise ValueError("prefill_tokens >= 0")
    if decode_lanes < 0:
        raise ValueError("decode_lanes >= 0")
    return max(0, token_budget - prefill_tokens - decode_lanes)


def tokens_per_step_cov(counts: "list[int] | list[float]") -> float:
    """Coefficient of variation of per-step token counts — the serving
    flatness metric (0 = perfectly flat traffic, the GPP ideal; the seed
    engine's prefill bursts push it >> 1)."""
    counts = [float(c) for c in counts]
    if not counts:
        return 0.0
    mean = sum(counts) / len(counts)
    if mean == 0:
        return 0.0
    return statistics.pstdev(counts) / mean


# ---------------------------------------------------------------------------
# Measured-timing feedback: TimingCache
# ---------------------------------------------------------------------------

TIMING_PROVENANCES = ("host", "compiled")


@dataclasses.dataclass(frozen=True)
class TimingSample:
    """One measured (transfer, compute) pair for a weight tile.

    block_bytes / compute_flops describe the tile the measurement was taken
    on; t_dma / t_compute are the measured wall-times [s] to move and to
    matmul that tile.  Rates (bytes/s, flop/s) are what the planner consumes,
    so samples at any tile size inform plans at every tile size.

    measured_on records provenance: "host" samples come from eager/CPU timing
    loops (dispatch overhead, no real HBM), "compiled" samples from a
    compiled run on the accelerator the plan will execute on.  Consumers
    (`TimingCache.effective_rates`) prefer compiled samples when any exist —
    a host-measured rate is a stand-in, not ground truth.
    """

    block_bytes: float
    compute_flops: float
    t_dma: float
    t_compute: float
    measured_on: str = "host"

    @property
    def bytes_per_s(self) -> float:
        return self.block_bytes / self.t_dma if self.t_dma > 0 else math.inf

    @property
    def flops_per_s(self) -> float:
        return self.compute_flops / self.t_compute if self.t_compute > 0 else math.inf


class TimingCache:
    """Measured per-tile t_dma/t_compute samples feeding `plan_matmul_tiles`.

    The analytic model (PEAK_FLOPS / HBM_BYTES_PER_S) is a datasheet ideal;
    real kernels see fused-epilogue overheads, DMA contention, and clock
    throttling.  `benchmarks/run.py` records what one tile *actually* costs
    on this host and the planner then sizes the ring against median measured
    rates instead of the ideal — the paper's runtime-adaptation loop
    (Fig 7) applied to the TPU mapping.
    """

    def __init__(self, samples: "list[TimingSample] | tuple[TimingSample, ...]" = ()):
        self._samples: list[TimingSample] = list(samples)

    def __len__(self) -> int:
        return len(self._samples)

    @property
    def samples(self) -> "tuple[TimingSample, ...]":
        return tuple(self._samples)

    def record(self, *, block_bytes: float, compute_flops: float,
               t_dma: float, t_compute: float,
               measured_on: str = "host") -> None:
        if block_bytes <= 0 or compute_flops <= 0:
            raise ValueError("block_bytes and compute_flops must be positive")
        if t_dma < 0 or t_compute < 0:
            raise ValueError("measured times must be non-negative")
        if measured_on not in TIMING_PROVENANCES:
            raise ValueError(
                f"measured_on must be one of {TIMING_PROVENANCES}, "
                f"got {measured_on!r}")
        self._samples.append(TimingSample(block_bytes, compute_flops,
                                          t_dma, t_compute, measured_on))

    def effective_rates(self) -> "tuple[float, float]":
        """(flops_per_s, transfer_bytes_per_s) — median of per-sample rates.

        Median (not mean): one cold-cache or preempted sample must not drag
        the plan; the planner wants the steady-state rate.  When any
        compiled-run samples exist they are used exclusively — host-measured
        rates (eager dispatch, no real HBM link) only stand in until a
        compiled path has been profiled.
        """
        if not self._samples:
            raise ValueError("TimingCache has no samples")
        pool = [s for s in self._samples if s.measured_on == "compiled"] \
            or self._samples
        fps = statistics.median(s.flops_per_s for s in pool)
        bps = statistics.median(s.bytes_per_s for s in pool)
        return fps, bps

    # ---- persistence (benchmarks/run.py emits, sessions consume) ----
    def to_json(self) -> "list[dict]":
        return [dataclasses.asdict(s) for s in self._samples]

    @classmethod
    def from_json(cls, entries: "list[dict]") -> "TimingCache":
        return cls([TimingSample(**e) for e in entries])

    @classmethod
    def from_bench_json(cls, path: str,
                        key: str = "dense_timing_samples") -> "TimingCache":
        """Load the samples `benchmarks/run.py` mirrors into
        BENCH_kernels.json (entry `key`, field "samples")."""
        import json
        with open(path) as f:
            bench = json.load(f)
        entry = bench.get(key) or {}
        return cls.from_json(entry.get("samples", []))


_DEFAULT_TIMING: "TimingCache | None" = None


def set_default_timing_cache(cache: "TimingCache | None") -> None:
    """Install measurements for every subsequent `plan_matmul_tiles` call
    that doesn't pass its own `timing` (None clears)."""
    global _DEFAULT_TIMING
    _DEFAULT_TIMING = cache


def get_default_timing_cache() -> "TimingCache | None":
    return _DEFAULT_TIMING


# ---------------------------------------------------------------------------
# M/K/N tile planner for the streaming matmul kernel (kernels/gpp_matmul.py)
# ---------------------------------------------------------------------------

VMEM_BUDGET_BYTES = 100 * 1024 * 1024  # target TPU v5e ~128 MiB/core, headroom

# TPU v5e hardware model — single source of truth, also used by kernels/ops.py
PEAK_FLOPS = 197e12
HBM_BYTES_PER_S = 819e9

_LANE = 128     # TPU lane width: block_n granularity
_SUBLANE = 8    # f32 sublane: block_m / block_k granularity


def round_up(x: int, mult: int) -> int:
    """Smallest multiple of `mult` >= x (tile, block, and chunk sizing)."""
    return ((x + mult - 1) // mult) * mult


def _resolve_rates(flops_per_s, transfer_bytes_per_s,
                   timing: "TimingCache | None") -> "tuple[float, float]":
    """Shared rate resolution for the tile/ring planners: an explicit
    `timing` cache (or, when nothing was passed, the ambient default cache)
    replaces the analytic datasheet constants with median measured rates;
    explicitly passed rate kwargs win over the ambient default."""
    if timing is None and flops_per_s is None and transfer_bytes_per_s is None:
        timing = _DEFAULT_TIMING
    if timing is not None and len(timing):
        flops_per_s, transfer_bytes_per_s = timing.effective_rates()
    if flops_per_s is None:
        flops_per_s = PEAK_FLOPS
    if transfer_bytes_per_s is None:
        transfer_bytes_per_s = HBM_BYTES_PER_S
    return flops_per_s, transfer_bytes_per_s


@dataclasses.dataclass(frozen=True)
class MatmulTilePlan:
    """Tile sizes + ring depth for the 3-D-grid GPP streaming matmul.

    The working set held on-chip is
      ring:   num_bufs * block_k * block_n * w_itemsize   (weight ring)
      x:      2 * block_m * block_k * x_itemsize          (pipelined in-block)
      y:      2 * block_m * block_n * out_itemsize        (pipelined out-block)
      acc:    block_m * block_n * 4                       (f32 accumulator)
    """

    block_m: int
    block_n: int
    block_k: int
    num_bufs: int
    vmem_bytes: int

    def grid(self, M: int, N: int, K: int) -> "tuple[int, int, int]":
        return (
            -(-M // self.block_m),
            -(-N // self.block_n),
            -(-K // self.block_k),
        )


def matmul_vmem_bytes(block_m: int, block_n: int, block_k: int, num_bufs: int,
                      *, x_itemsize: int, w_itemsize: int,
                      out_itemsize: int) -> int:
    return (
        num_bufs * block_k * block_n * w_itemsize
        + 2 * block_m * block_k * x_itemsize
        + 2 * block_m * block_n * out_itemsize
        + block_m * block_n * 4
    )


def plan_matmul_tiles(
    M: int,
    K: int,
    N: int,
    *,
    x_itemsize: int = 4,
    w_itemsize: int = 4,
    out_itemsize: int = 4,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    num_bufs: int | None = None,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    max_ring: int = 8,
    flops_per_s: "float | None" = None,
    transfer_bytes_per_s: "float | None" = None,
    timing: "TimingCache | None" = None,
) -> MatmulTilePlan:
    """Pick (block_m, block_n, block_k, num_bufs) under the VMEM budget.

    Caller-pinned dims are honored as-is; unpinned dims start from defaults
    and shrink (block_k first, then block_m, then ring depth, then block_n)
    until the working set fits, instead of erroring like the old 1-D kernel.
    Raises only if the *pinned* configuration cannot fit at minimum sizes of
    every free dim.

    `timing` (or, when omitted, the cache installed via
    `set_default_timing_cache`) replaces the analytic flops_per_s /
    transfer_bytes_per_s constants (the None-defaults of the rate kwargs)
    with median *measured* rates, so the ring depth tracks what one tile
    actually costs on this host rather than the datasheet ideal.  An
    explicitly passed rate kwarg wins over the ambient default cache (but
    not over an explicitly passed `timing`).
    """
    flops_per_s, transfer_bytes_per_s = _resolve_rates(
        flops_per_s, transfer_bytes_per_s, timing)
    if M < 1 or K < 1 or N < 1:
        raise ValueError(f"bad matmul shape M={M} K={K} N={N}")
    if num_bufs is not None and num_bufs < 1:
        raise ValueError("num_bufs >= 1")
    bn = block_n if block_n is not None else min(round_up(N, _LANE), 256)
    bm = block_m if block_m is not None else min(round_up(M, _SUBLANE), 512)
    bk = block_k if block_k is not None else min(round_up(K, _SUBLANE), 2048)

    def ring_for(bm_, bk_, bn_):
        if num_bufs is not None:
            return num_bufs
        plan = plan_stream(
            block_bytes=bk_ * bn_ * w_itemsize,
            compute_flops=2.0 * bm_ * bk_ * bn_,
            flops_per_s=flops_per_s,
            transfer_bytes_per_s=transfer_bytes_per_s,
            max_ring=max_ring,
        )
        return plan.ring_depth

    def fits(bm_, bk_, bn_, g_):
        return matmul_vmem_bytes(
            bm_, bn_, bk_, g_, x_itemsize=x_itemsize, w_itemsize=w_itemsize,
            out_itemsize=out_itemsize) <= vmem_budget

    g = ring_for(bm, bk, bn)
    while not fits(bm, bk, bn, g):
        if block_k is None and bk > _LANE:
            bk = max(_LANE, round_up(bk // 2, _SUBLANE))
        elif block_m is None and bm > _SUBLANE:
            bm = max(_SUBLANE, round_up(bm // 2, _SUBLANE))
        elif num_bufs is None and g > 1:
            g -= 1          # last resort ends at in-situ (G=1), a valid mode
            continue
        elif block_n is None and bn > _LANE:
            bn = max(_LANE, round_up(bn // 2, _LANE))
        else:
            used = matmul_vmem_bytes(
                bm, bn, bk, g, x_itemsize=x_itemsize, w_itemsize=w_itemsize,
                out_itemsize=out_itemsize)
            raise ValueError(
                f"matmul working set {used / 2**20:.1f} MiB exceeds the "
                f"{vmem_budget / 2**20:.0f} MiB VMEM budget even at minimum "
                f"free-tile sizes (pinned: block_m={block_m} block_n={block_n} "
                f"block_k={block_k} num_bufs={num_bufs})"
            )
        g = ring_for(bm, bk, bn)

    # grow an unpinned block_m back toward M while the budget allows: every
    # extra m-pass re-streams the whole weight matrix from HBM, which is
    # exactly the traffic this kernel exists to minimize.
    if block_m is None:
        M_full = round_up(M, _SUBLANE)
        while bm < M_full:
            bm_try = min(M_full, bm * 2)
            g_try = ring_for(bm_try, bk, bn)
            if not fits(bm_try, bk, bn, g_try):
                break
            bm, g = bm_try, g_try

    used = matmul_vmem_bytes(
        bm, bn, bk, g, x_itemsize=x_itemsize, w_itemsize=w_itemsize,
        out_itemsize=out_itemsize)
    return MatmulTilePlan(block_m=bm, block_n=bn, block_k=bk, num_bufs=g,
                          vmem_bytes=used)


# ---------------------------------------------------------------------------
# KV-block ring planner for the paged-attention kernel
# (kernels/paged_attention.py)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PagedAttnPlan:
    """Ring depth + chunking for streaming KV blocks through VMEM.

    The paged-attention kernel is the GPP schedule applied to the attention
    read path: a physical KV block is the "macro", its HBM->VMEM DMA the
    "rewrite", the per-block online-softmax flash step the "compute".
    num_bufs is the KV-block ring depth G (1 in-situ, 2 naive ping-pong,
    >= 3 generalized ping-pong with C = G-1 chunks per block).
    """

    num_bufs: int
    chunks: int
    vmem_bytes: int


def plan_paged_attn(
    *,
    block_bytes: int,
    compute_flops: float,
    fixed_bytes: int = 0,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    max_ring: int = 8,
    num_bufs: "int | None" = None,
    flops_per_s: "float | None" = None,
    transfer_bytes_per_s: "float | None" = None,
    timing: "TimingCache | None" = None,
) -> PagedAttnPlan:
    """Pick the KV-block ring depth for the paged-attention kernel.

    block_bytes    bytes one logical KV block moves HBM->VMEM per grid step
                   (both pools: k+v, or c_kv+k_rope)
    compute_flops  flops of one per-block flash step (QK^T + PV)
    fixed_bytes    non-ring VMEM working set (queries, accumulator, output)

    Rates come from the same measured-feedback path as `plan_matmul_tiles`:
    an explicit/ambient `TimingCache` overrides the analytic constants, with
    compiled-run samples preferred over host ones.  The ring shrinks (never
    errors) until fixed + G*block_bytes fits the VMEM budget.
    """
    if block_bytes <= 0:
        raise ValueError("block_bytes must be positive")
    flops_per_s, transfer_bytes_per_s = _resolve_rates(
        flops_per_s, transfer_bytes_per_s, timing)
    if num_bufs is not None:
        if num_bufs < 1:
            raise ValueError("num_bufs >= 1")
        g = num_bufs
    else:
        g = plan_stream(
            block_bytes=block_bytes,
            compute_flops=compute_flops,
            flops_per_s=flops_per_s,
            transfer_bytes_per_s=transfer_bytes_per_s,
            max_ring=max_ring,
        ).ring_depth
        while g > 1 and fixed_bytes + g * block_bytes > vmem_budget:
            g -= 1      # shrink toward in-situ instead of erroring
    used = fixed_bytes + g * block_bytes
    if used > vmem_budget and num_bufs is None:
        raise ValueError(
            f"paged-attention working set {used / 2**20:.1f} MiB exceeds the "
            f"{vmem_budget / 2**20:.0f} MiB VMEM budget even at ring depth 1")
    return PagedAttnPlan(num_bufs=g, chunks=max(1, g - 1), vmem_bytes=used)
