"""Design-phase design-space exploration with generalized ping-pong.

Reproduces paper Fig 6 (execution time & macro count vs t_rw:t_pim ratio at
fixed off-chip bandwidth) and Table II (theory vs integer practice under a
fixed total on-chip buffer budget).

Table II derivation (verified against every row of the paper):
  design point: band_design = 512 B/cycle, s = 8, size_macro = 1024 B,
  size_ou = 32 B/cycle, n_in = 4  =>  t_pim = t_rw = 128, num = 128 macros,
  total buffer budget K = num * n_in = 512 input-vector slots.
  At reduced band, GPP picks r = t_pim':t_rw from  r(1+r) = K*s^2/(4*ou*band)
  (= 1024/band here), giving num = (1+r)*band/s and perf = num*r/(1+r) / 64.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core import analytical as ana
from repro.core import simulator as dessim
from repro.core.analytical import PimConfig


@dataclasses.dataclass(frozen=True)
class DsePoint:
    strategy: str
    ratio_rw_over_pim: float
    num_macros: float
    exec_time: float           # cycles for the reference workload
    peak_bandwidth: float      # B/cycle


def fig6_sweep(
    cfg: PimConfig,
    ratios: "list[float]",
    *,
    workload_rounds: int = 64,
) -> "list[DsePoint]":
    """Sweep t_rw:t_pim (by adjusting n_in) at fixed band; for each strategy
    size the accelerator per Eqs 3-4 and measure the latency of a fixed
    workload (`workload_rounds * num_gpp_macros` macro-GeMMs) with the DES.
    """
    out: list[DsePoint] = []
    for ratio in ratios:  # ratio = t_rw / t_pim
        # choose n_in to hit the ratio: t_rw/t_pim = size_ou/(n_in*s)
        n_in = cfg.size_ou / (cfg.s * ratio)
        c = cfg.with_(n_in=n_in)
        work = workload_rounds * max(
            1, round(ana.num_macros(c, "gpp"))
        )  # total macro-GeMMs, fixed across strategies
        for strat in ana.STRATEGIES:
            n = max(1, round(ana.num_macros(c, strat)))
            rounds = max(1, math.ceil(work / n))
            res = dessim.simulate(strat, c, n, rounds)
            out.append(
                DsePoint(
                    strategy=strat,
                    ratio_rw_over_pim=ratio,
                    num_macros=n,
                    exec_time=res.total_cycles * (work / (n * rounds)),
                    peak_bandwidth=res.peak_bandwidth,
                )
            )
    return out


@dataclasses.dataclass(frozen=True)
class TableIIRow:
    band: float
    macros_theory: float
    macros_practice: int
    ratio_theory: float        # t_pim : t_rw
    ratio_practice: float
    perf_theory: float         # remaining perf vs design point
    perf_practice: float


# Table II design point (see module docstring).
TABLE2_CFG = PimConfig(size_macro=1024, size_ou=32, s=8.0, n_in=4.0, band=512.0)
TABLE2_BUFFER = 512            # total n_in slots across macros
TABLE2_DESIGN_EQUIV = 64.0     # fully-busy macro-equivalents at design point


def table2_theory(band: float, cfg: PimConfig = TABLE2_CFG) -> "tuple[float, float, float]":
    """Closed-form (macros, t_pim:t_rw, remaining perf) at reduced `band`."""
    k_buf = TABLE2_BUFFER
    # r(1+r) = K*s^2/(4... ) — generally: num*n_in = K, num = (1+r)*band/s,
    # n_in = r*size_ou/s  =>  r(1+r) = K*s^2/(size_ou*band)
    c = k_buf * cfg.s * cfg.s / (cfg.size_ou * band)
    r = (-1.0 + math.sqrt(1.0 + 4.0 * c)) / 2.0
    num = (1.0 + r) * band / cfg.s
    perf = num * r / (1.0 + r) / TABLE2_DESIGN_EQUIV
    return num, r, perf


def table2_practice(band: float, cfg: PimConfig = TABLE2_CFG) -> "tuple[int, float, float]":
    """Integer-feasible operating point: integer n_in and integer macros,
    maximizing throughput subject to the buffer budget and avg-bandwidth
    constraint, validated with the cycle-accurate simulator."""
    best = (0, 0.0, 0.0)
    for n_in in range(1, TABLE2_BUFFER + 1):
        r = n_in * cfg.s / cfg.size_ou  # t_pim : t_rw
        by_buffer = TABLE2_BUFFER // n_in
        by_band = math.floor((1.0 + r) * band / cfg.s)
        num = min(by_buffer, by_band)
        if num < 1:
            continue
        perf = num * r / (1.0 + r) / TABLE2_DESIGN_EQUIV
        if perf > best[2]:
            best = (num, r, perf)
    return best


def table2(bands=(256, 128, 64, 32, 16, 8)) -> "list[TableIIRow]":
    rows = []
    for band in bands:
        nt, rt, pt = table2_theory(float(band))
        np_, rp, pp = table2_practice(float(band))
        rows.append(
            TableIIRow(
                band=float(band),
                macros_theory=nt,
                macros_practice=np_,
                ratio_theory=rt,
                ratio_practice=rp,
                perf_theory=pt,
                perf_practice=pp,
            )
        )
    return rows
