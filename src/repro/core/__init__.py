"""Generalized ping-pong (GPP) — the paper's contribution.

Layers:
  analytical     closed-form model (paper Eqs 1-9)
  schedule       schedule IR + in-situ / naive ping-pong / GPP builders
  simulator      cycle-accurate discrete-event simulation (Verilog stand-in)
  dse            design-phase exploration (Fig 6, Table II)
  runtime_adapt  runtime bandwidth adaptation (Fig 7)
  streamer       the JAX realization: GPP weight-streaming executors
"""
from repro.core.analytical import PimConfig, STRATEGIES
from repro.core.schedule import Schedule, ScheduleOp, StreamPlan, build, plan_stream
from repro.core.simulator import SimResult, simulate

__all__ = [
    "PimConfig",
    "STRATEGIES",
    "Schedule",
    "ScheduleOp",
    "StreamPlan",
    "build",
    "plan_stream",
    "SimResult",
    "simulate",
]
