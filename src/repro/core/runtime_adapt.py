"""Runtime-phase pipeline adaptation under off-chip bandwidth reduction.

Reproduces paper §IV-C / Fig 7: when an SoC cuts the PIM accelerator's
off-chip bandwidth to band/n at runtime, each strategy adapts differently:

  insitu    keep all macros, slow each rewrite n×            (Eq 7)
  naive_pp  keep rewrite speed at the t_pim==t_rw matching point, cut the
            number of active macro pairs                     (Eq 8)
  gpp       keep rewrite speed, cut active macros to num/m and give each
            survivor m× the on-chip buffer (n_in *= m), re-staggering so the
            reduced bandwidth is still flat-saturated        (Eq 9)

Each adaptation is evaluated both in closed form (analytical.py) and with the
cycle-accurate simulator on the adapted operating point.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core import analytical as ana
from repro.core import simulator as dessim
from repro.core.analytical import PimConfig


@dataclasses.dataclass(frozen=True)
class AdaptPoint:
    strategy: str
    band_reduction: float          # n: bandwidth is band/n
    active_macros: int
    perf_theory: float             # remaining performance (closed form)
    perf_sim: float                # remaining performance (DES)
    bw_utilization: float          # fraction of cycles with bus traffic (sim)
    macro_utilization: float       # busy fraction of *active* macros (sim)
    buffer_utilization: float      # used n_in slots / total buffer budget


def _design_point(cfg: PimConfig, strategy: str) -> "tuple[PimConfig, int]":
    """Design-phase anchor: t_pim == t_rw (paper's Fig 7 anchor) at cfg.band,
    with each strategy sized by its own Eq 3/4 optimum."""
    n_in_match = cfg.size_ou / cfg.s  # makes t_pim == t_rw
    c = cfg.with_(n_in=n_in_match)
    num = max(2, round(ana.num_macros(c, strategy)))
    return c, num


def adapt_insitu(cfg: PimConfig, n: float, rounds: int = 16) -> AdaptPoint:
    c, num = _design_point(cfg, "insitu")
    perf_th = ana.insitu_perf_degradation(c, n)
    reduced = c.with_(band=c.band / n)
    res = dessim.simulate("insitu", reduced, num, rounds)
    base = dessim.simulate("insitu", c, num, rounds)
    return AdaptPoint(
        strategy="insitu",
        band_reduction=n,
        active_macros=num,
        perf_theory=perf_th,
        perf_sim=base.total_cycles / res.total_cycles,
        bw_utilization=res.bandwidth_utilization,
        macro_utilization=res.macro_utilization,
        buffer_utilization=1.0,  # all macros keep their buffers
    )


def adapt_naive_pp(cfg: PimConfig, n: float, rounds: int = 16) -> AdaptPoint:
    c, num = _design_point(cfg, "naive_pp")
    perf_th = ana.naive_pp_perf_degradation(c, n)
    # keep per-macro rewrite speed s; active pairs limited by band/n:
    # each pair's average demand is s/2 => active = 2*(band/n)/s macros.
    active = max(2, 2 * math.floor((c.band / n) / c.s))
    active = min(active, num)
    reduced = c.with_(band=c.band / n)
    res = dessim.simulate("naive_pp", reduced, active, rounds)
    base = dessim.simulate("naive_pp", c, num, rounds)
    # throughput is per-macro-round; scale by active/num macros
    perf_sim = (res.throughput) / (base.throughput)
    used_buffer = active * c.n_in
    return AdaptPoint(
        strategy="naive_pp",
        band_reduction=n,
        active_macros=active,
        perf_theory=perf_th,
        perf_sim=perf_sim,
        bw_utilization=res.bandwidth_utilization,
        macro_utilization=res.macro_utilization,
        buffer_utilization=used_buffer / (num * c.n_in),
    )


def adapt_gpp(cfg: PimConfig, n: float, rounds: int = 16) -> AdaptPoint:
    c, num = _design_point(cfg, "gpp")
    perf_th = ana.gpp_perf_degradation(c, n)
    # perf = (1+r0)/(1+r') with r0 = 1 at the anchor; the survivors' compute:
    # rewrite ratio r' solves r'(1+r') = num*r0*s*n/band (Eq 9 rearranged).
    r0 = 1.0
    rp = (-1.0 + math.sqrt(1.0 + 4.0 * num * r0 * c.s * n / c.band)) / 2.0
    active = max(1, round(num * r0 / rp))
    # survivors inherit the freed buffers: n_in' = n_in * (num/active)
    n_in_new = c.n_in * num / active
    adapted = c.with_(n_in=n_in_new, band=c.band / n)
    res = dessim.simulate("gpp", adapted, active, rounds)
    base = dessim.simulate("gpp", c, num, rounds)
    # per-round useful work scales with n_in: account for it
    work_res = active * rounds * n_in_new
    work_base = num * rounds * c.n_in
    perf_sim = (work_res / res.total_cycles) / (work_base / base.total_cycles)
    return AdaptPoint(
        strategy="gpp",
        band_reduction=n,
        active_macros=active,
        perf_theory=perf_th,
        perf_sim=perf_sim,
        bw_utilization=res.bandwidth_utilization,
        macro_utilization=res.macro_utilization,
        buffer_utilization=(active * n_in_new) / (num * c.n_in),
    )


def fig7_sweep(
    cfg: PimConfig | None = None,
    reductions=(1, 2, 4, 8, 16, 32, 64),
    rounds: int = 16,
) -> "list[AdaptPoint]":
    """Full Fig 7 sweep for the three strategies."""
    cfg = cfg or PimConfig(size_macro=1024, size_ou=32, s=8.0, band=512.0)
    out: list[AdaptPoint] = []
    for n in reductions:
        out.append(adapt_insitu(cfg, float(n), rounds))
        out.append(adapt_naive_pp(cfg, float(n), rounds))
        out.append(adapt_gpp(cfg, float(n), rounds))
    return out
