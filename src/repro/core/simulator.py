"""Cycle-accurate discrete-event simulator for multi-macro PIM pipelines.

Stand-in for the paper's synthesizable-Verilog timing simulation: N macros
share one off-chip bus of `band` bytes/cycle through a fair arbiter (each
active rewriter gets min(s, band/k) for k rewriters); each macro must
  rewrite(size_macro bytes)  then  compute(t_pim cycles)
for each of `rounds` consecutive GeMMs (weights change every round — the
streaming regime the paper targets).  Strategies differ in *when* a macro may
start each phase:

  insitu    all macros synchronize on both phase boundaries (Fig 3a)
  naive_pp  two banks, synchronized swap: one computes GeMM n while the other
            rewrites weights for GeMM n+1 (Fig 3b)
  gpp       staggered free-running macros per schedule.build_gpp (Fig 3c)

Simulation is exact event-driven integration (rates are piecewise constant),
no time-step quantization.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.analytical import PimConfig
from repro.core import schedule as sched

_EPS = 1e-9


@dataclasses.dataclass
class SimResult:
    strategy: str
    num_macros: int
    rounds: int
    total_cycles: float
    compute_cycles: float      # sum over macros of cycles spent computing
    rewrite_cycles: float      # sum over macros of cycles spent rewriting
    bytes_transferred: float
    peak_bandwidth: float      # max instantaneous bus demand [B/cycle]
    bw_busy_cycles: float      # cycles with nonzero bus traffic

    @property
    def macro_utilization(self) -> float:
        """Busy (compute or rewrite) fraction averaged over macros."""
        return (self.compute_cycles + self.rewrite_cycles) / (
            self.total_cycles * self.num_macros
        )

    @property
    def compute_utilization(self) -> float:
        """Computing fraction averaged over macros (Fig 7d notion)."""
        return self.compute_cycles / (self.total_cycles * self.num_macros)

    @property
    def bandwidth_utilization(self) -> float:
        """Fraction of cycles with bus traffic in flight (Fig 7c)."""
        return self.bw_busy_cycles / self.total_cycles

    @property
    def avg_bandwidth(self) -> float:
        return self.bytes_transferred / self.total_cycles

    @property
    def throughput(self) -> float:
        """Completed macro-GeMM rounds per cycle."""
        return self.num_macros * self.rounds / self.total_cycles


def _rewrite_time(cfg: PimConfig, k: int) -> float:
    """Cycles for k macros to rewrite concurrently through the arbiter."""
    if k == 0:
        return 0.0
    rate = min(cfg.s, cfg.band / k)
    return cfg.size_macro / rate


def simulate_insitu(cfg: PimConfig, num_macros: int, rounds: int) -> SimResult:
    tp = cfg.time_pim
    tr = _rewrite_time(cfg, num_macros)
    rate = min(cfg.s, cfg.band / num_macros) * num_macros
    total = rounds * (tr + tp)
    return SimResult(
        strategy="insitu",
        num_macros=num_macros,
        rounds=rounds,
        total_cycles=total,
        compute_cycles=num_macros * rounds * tp,
        rewrite_cycles=num_macros * rounds * tr,
        bytes_transferred=num_macros * rounds * cfg.size_macro,
        peak_bandwidth=rate,
        bw_busy_cycles=rounds * tr,
    )


def simulate_naive_pp(cfg: PimConfig, num_macros: int, rounds: int) -> SimResult:
    """Two synchronized banks; each macro computes `rounds` GeMMs.

    Phase p: bank (p%2) computes its current round; the other bank rewrites
    its next round (if any).  Both must finish before the swap (barrier).
    """
    tp = cfg.time_pim
    half = num_macros - num_macros // 2  # bank0 size (>= bank1)
    sizes = (half, num_macros - half)
    tr = [_rewrite_time(cfg, k) for k in sizes]
    loaded = [0, 0]       # rounds of weights loaded per bank
    done = [0, 0]         # rounds computed per bank
    t = 0.0
    compute_cycles = rewrite_cycles = bytes_moved = bw_busy = 0.0
    peak_bw = 0.0

    # warm-up: bank0 rewrites its first weights alone
    t += tr[0]
    bw_busy += tr[0]
    rewrite_cycles += sizes[0] * tr[0]
    bytes_moved += sizes[0] * cfg.size_macro
    peak_bw = max(peak_bw, min(cfg.s, cfg.band / sizes[0]) * sizes[0])
    loaded[0] = 1

    p = 0
    guard = 0
    while done[0] < rounds or done[1] < rounds:
        guard += 1
        if guard > 8 * rounds + 64:
            raise RuntimeError("naive_pp wedged")
        cb, rb = p % 2, 1 - p % 2
        dur_c = tp if (done[cb] < rounds and loaded[cb] > done[cb]) else 0.0
        needs_rw = loaded[rb] < rounds
        dur_r = tr[rb] if needs_rw and sizes[rb] else 0.0
        dur = max(dur_c, dur_r)
        if dur == 0.0:
            p += 1
            continue
        if dur_c:
            compute_cycles += sizes[cb] * tp
            done[cb] += 1
        if dur_r:
            rewrite_cycles += sizes[rb] * dur_r
            bytes_moved += sizes[rb] * cfg.size_macro
            bw_busy += dur_r
            peak_bw = max(peak_bw, min(cfg.s, cfg.band / sizes[rb]) * sizes[rb])
            loaded[rb] += 1
        t += dur
        p += 1

    return SimResult(
        strategy="naive_pp",
        num_macros=num_macros,
        rounds=rounds,
        total_cycles=t,
        compute_cycles=compute_cycles,
        rewrite_cycles=rewrite_cycles,
        bytes_transferred=bytes_moved,
        peak_bandwidth=peak_bw,
        bw_busy_cycles=bw_busy,
    )


def simulate_gpp(cfg: PimConfig, num_macros: int, rounds: int) -> SimResult:
    """Staggered free-running macros with a fair bus arbiter (event-driven).

    Vectorized over macros with numpy: per-event work is O(1) numpy kernels
    instead of Python for-loops over every macro, so `num_macros >= 1024`
    DSE sweeps (core/dse.py) stop being quadratic in Python.  Event semantics
    are identical to `simulate_gpp_scalar` (asserted by
    tests/test_sim_vectorized.py).
    """
    tp = cfg.time_pim
    size = cfg.size_macro
    period = tp + cfg.time_rewrite
    groups = sched.gpp_group_count(cfg)

    WAIT, REWRITE, COMPUTE, DONE = range(4)
    phase = np.full(num_macros, WAIT, dtype=np.int8)
    remaining = np.zeros(num_macros, dtype=np.float64)
    round_no = np.zeros(num_macros, dtype=np.int64)
    release = (np.arange(num_macros) % groups) * (period / groups)

    t = 0.0
    compute_cycles = rewrite_cycles = bytes_moved = bw_busy = 0.0
    peak_bw = 0.0
    guard = 0
    max_events = 16 * num_macros * rounds + 4096

    while (phase != DONE).any():
        guard += 1
        if guard > max_events:
            raise RuntimeError(f"gpp sim wedged N={num_macros}")
        # admit waiting macros whose stagger release has passed
        admit = (phase == WAIT) & (t + _EPS >= release)
        phase[admit] = REWRITE
        remaining[admit] = size

        rewriting = phase == REWRITE
        computing = phase == COMPUTE
        waiting = phase == WAIT
        k = int(rewriting.sum())
        rate = min(cfg.s, cfg.band / k) if k else 0.0
        bus = rate * k
        peak_bw = max(peak_bw, bus)

        dt = math.inf
        if k and rate > 0:
            dt = min(dt, float(remaining[rewriting].min()) / rate)
        if computing.any():
            dt = min(dt, float(remaining[computing].min()))
        if waiting.any():
            dt = min(dt, float(np.maximum(_EPS, release[waiting] - t).min()))
        if not math.isfinite(dt):
            raise RuntimeError("gpp sim: no runnable macro")

        t += dt
        if bus > 0:
            bw_busy += dt
            bytes_moved += bus * dt
        if k:
            remaining[rewriting] -= rate * dt
            rewrite_cycles += k * dt
            rw_done = rewriting & (remaining <= _EPS * size)
            phase[rw_done] = COMPUTE
            remaining[rw_done] = tp
        if computing.any():
            remaining[computing] -= dt
            compute_cycles += int(computing.sum()) * dt
            cp_done = computing & (remaining <= _EPS * max(tp, 1.0))
            round_no[cp_done] += 1
            finished = cp_done & (round_no >= rounds)
            again = cp_done & ~finished
            phase[finished] = DONE
            phase[again] = REWRITE
            remaining[again] = size

    return SimResult(
        strategy="gpp",
        num_macros=num_macros,
        rounds=rounds,
        total_cycles=t,
        compute_cycles=compute_cycles,
        rewrite_cycles=rewrite_cycles,
        bytes_transferred=bytes_moved,
        peak_bandwidth=peak_bw,
        bw_busy_cycles=bw_busy,
    )


def simulate_gpp_scalar(cfg: PimConfig, num_macros: int, rounds: int) -> SimResult:
    """Reference scalar event loop (pre-vectorization), kept as the oracle for
    the numpy path above — one Python iteration per macro per event."""
    tp = cfg.time_pim
    size = cfg.size_macro
    period = tp + cfg.time_rewrite
    groups = sched.gpp_group_count(cfg)

    WAIT, REWRITE, COMPUTE, DONE = range(4)
    phase = [WAIT] * num_macros
    remaining = [0.0] * num_macros
    round_no = [0] * num_macros
    release = [(m % groups) * period / groups for m in range(num_macros)]

    t = 0.0
    compute_cycles = rewrite_cycles = bytes_moved = bw_busy = 0.0
    peak_bw = 0.0
    guard = 0
    max_events = 16 * num_macros * rounds + 4096

    while any(p != DONE for p in phase):
        guard += 1
        if guard > max_events:
            raise RuntimeError(f"gpp sim wedged N={num_macros}")
        # admit waiting macros whose stagger release has passed
        for m in range(num_macros):
            if phase[m] == WAIT and t + _EPS >= release[m]:
                phase[m] = REWRITE
                remaining[m] = size

        rewriters = [m for m in range(num_macros) if phase[m] == REWRITE]
        k = len(rewriters)
        rate = min(cfg.s, cfg.band / k) if k else 0.0
        bus = rate * k
        peak_bw = max(peak_bw, bus)

        dt = math.inf
        for m in range(num_macros):
            if phase[m] == REWRITE and rate > 0:
                dt = min(dt, remaining[m] / rate)
            elif phase[m] == COMPUTE:
                dt = min(dt, remaining[m])
            elif phase[m] == WAIT:
                dt = min(dt, max(_EPS, release[m] - t))
        if not math.isfinite(dt):
            raise RuntimeError("gpp sim: no runnable macro")

        t += dt
        if bus > 0:
            bw_busy += dt
            bytes_moved += bus * dt
        for m in range(num_macros):
            if phase[m] == REWRITE:
                remaining[m] -= rate * dt
                rewrite_cycles += dt
                if remaining[m] <= _EPS * size:
                    phase[m] = COMPUTE
                    remaining[m] = tp
            elif phase[m] == COMPUTE:
                remaining[m] -= dt
                compute_cycles += dt
                if remaining[m] <= _EPS * max(tp, 1.0):
                    round_no[m] += 1
                    if round_no[m] >= rounds:
                        phase[m] = DONE
                    else:
                        phase[m] = REWRITE
                        remaining[m] = size

    return SimResult(
        strategy="gpp",
        num_macros=num_macros,
        rounds=rounds,
        total_cycles=t,
        compute_cycles=compute_cycles,
        rewrite_cycles=rewrite_cycles,
        bytes_transferred=bytes_moved,
        peak_bandwidth=peak_bw,
        bw_busy_cycles=bw_busy,
    )


def simulate(strategy: str, cfg: PimConfig, num_macros: int, rounds: int) -> SimResult:
    fn = {
        "insitu": simulate_insitu,
        "naive_pp": simulate_naive_pp,
        "gpp": simulate_gpp,
    }.get(strategy)
    if fn is None:
        raise ValueError(f"unknown strategy {strategy!r}")
    if num_macros < 1 or rounds < 1:
        raise ValueError("num_macros and rounds must be >= 1")
    return fn(cfg, num_macros, rounds)
