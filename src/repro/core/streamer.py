"""JAX realization of generalized ping-pong: streamed layer execution.

The pod-scale mapping of the paper (DESIGN.md §2.2): layer weights are
FSDP-sharded over the `data` mesh axis ("off-chip"), and must be gathered
("rewritten") into replicated form before a layer's GeMMs ("compute").  The
four modes mirror the paper's strategies:

  resident   weights already replicated — no streaming (baseline TP/DP)
  insitu     gather layer i, then compute layer i: the gather is on the
             critical path every step (bursty + stalls)
  naive_pp   double-buffer: gather layer i+1 (whole) while computing layer i
             — classic FSDP prefetch; bursty when t_gather ≉ t_compute
  gpp        ring of G buffers; each step chunk-gathers 1/(G-1) of each of
             the next G-1 layers, so per-step collective bytes are flat at
             exactly one layer and compute never waits even when
             t_gather > t_compute

The ring schedule: layer j's bytes arrive during steps j-(G-1) … j-1; at
step i we fetch chunk (G-1-k) of layer i+k for k = 1..G-1.  Chunk indices
are static; only the layer index is dynamic (lax.dynamic_index_in_dim).
Backward of the gather is a reduce-scatter, so `stream_layers` is
differentiable and training gets ZeRO-3 semantics for free.

Ring depth comes from `repro.core.schedule.plan_stream` — the same planner
validated against the paper's analytic model.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.schedule import StreamPlan, plan_stream

Pytree = Any

MODES = ("resident", "insitu", "naive_pp", "gpp")


@dataclasses.dataclass(frozen=True)
class StreamSettings:
    """Per-model streaming configuration (part of the arch config)."""

    mode: str = "resident"
    ring_depth: int = 4          # G: buffers held (gpp); >= 2
    chunk_dim: int = -1          # which dim of each leaf to chunk-gather along
    fsdp_axis: str = "data"      # mesh axis the weights are sharded over

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {self.mode!r}")
        if self.ring_depth < 2:
            raise ValueError("ring_depth must be >= 2")


def _constrain(tree: Pytree, specs: Pytree, mesh: Mesh | None) -> Pytree:
    """with_sharding_constraint that tolerates mesh-less (single-device) runs."""
    if mesh is None or mesh.empty:
        return tree
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s)),
        tree,
        specs,
        is_leaf=lambda x: x is None,
    )


def _layer(ws: Pytree, i) -> Pytree:
    """Dynamic-index layer i out of leading-L stacked params."""
    return jax.tree.map(lambda w: jax.lax.dynamic_index_in_dim(w, i, 0, keepdims=False), ws)


def _chunk_bounds(dim_size: int, chunks: int, c: int) -> tuple[int, int]:
    """Static [lo, hi) bounds of chunk c (last chunk absorbs the remainder)."""
    base = dim_size // chunks
    lo = c * base
    hi = dim_size if c == chunks - 1 else lo + base
    return lo, hi


def _take_chunk(leaf: jnp.ndarray, chunk_dim: int, chunks: int, c: int) -> jnp.ndarray:
    d = chunk_dim % leaf.ndim
    lo, hi = _chunk_bounds(leaf.shape[d], chunks, c)
    idx = [slice(None)] * leaf.ndim
    idx[d] = slice(lo, hi)
    return leaf[tuple(idx)]


def _put_chunk(buf: jnp.ndarray, chunk: jnp.ndarray, chunk_dim: int, chunks: int, c: int) -> jnp.ndarray:
    d = chunk_dim % buf.ndim
    lo, _ = _chunk_bounds(buf.shape[d], chunks, c)
    start = [0] * buf.ndim
    start[d] = lo
    return jax.lax.dynamic_update_slice(buf, chunk.astype(buf.dtype), tuple(start))


def stream_layers(
    apply_fn: Callable[[Pytree, Pytree], Pytree],
    carry_init: Pytree,
    stacked_ws: Pytree,
    num_layers: int,
    *,
    settings: StreamSettings,
    mesh: Mesh | None,
    shard_specs: Pytree,
    full_specs: Pytree,
) -> Pytree:
    """Run `carry = apply_fn(carry, w_l)` over L stacked layers with the
    selected write/compute schedule.

    stacked_ws   pytree whose leaves have leading dim L, FSDP-sharded per
                 `shard_specs` (PartitionSpec for ONE layer, without the L dim)
    shard_specs / full_specs
                 per-leaf PartitionSpec before/after the gather; the gather is
                 `with_sharding_constraint(w, full_spec)` (XLA emits the
                 all-gather over the fsdp axis, reduce-scatter in backward)
    """
    mode = settings.mode
    lspec = jax.tree.map(lambda s: P(*(None, *s)), shard_specs)  # with L dim

    def gather(w_layer: Pytree) -> Pytree:
        return _constrain(w_layer, full_specs, mesh)

    if mode == "resident":
        def body(c, w):
            return apply_fn(c, w), None
        carry, _ = jax.lax.scan(body, carry_init, stacked_ws)
        return carry

    if mode == "insitu":
        def body(c, w):
            return apply_fn(c, gather(w)), None
        carry, _ = jax.lax.scan(body, carry_init, stacked_ws)
        return carry

    if mode == "naive_pp":
        # carry holds the gathered weights of the layer about to run.
        w0 = gather(_layer(stacked_ws, 0))

        def body(state, i):
            c, w_cur = state
            # issue next layer's (whole-layer) gather, then compute: XLA's
            # latency-hiding scheduler may overlap them — the naive ping-pong.
            w_next = gather(_layer(stacked_ws, jnp.minimum(i + 1, num_layers - 1)))
            c = apply_fn(c, w_cur)
            return (c, w_next), None

        (carry, _w), _ = jax.lax.scan(body, (carry_init, w0), jnp.arange(num_layers))
        return carry

    # ---- gpp ----
    G = max(2, min(settings.ring_depth, num_layers))
    chunks = max(1, G - 1)
    cd = settings.chunk_dim

    def gather_chunk(w_layer: Pytree, c: int) -> Pytree:
        chunk = jax.tree.map(lambda w: _take_chunk(w, cd, chunks, c), w_layer)
        spec_chunk = full_specs  # chunk keeps the gathered layout
        return _constrain(chunk, spec_chunk, mesh)

    # ring: G fully-materialized (gathered-layout) buffers.
    def zeros_like_full(w_layer: Pytree) -> Pytree:
        return jax.tree.map(jnp.zeros_like, w_layer)

    proto = gather(_layer(stacked_ws, 0))
    ring = jax.tree.map(
        lambda w: jnp.broadcast_to(jnp.zeros_like(w), (G, *w.shape)).copy(), proto
    )

    def ring_put_layer(ring, slot, w_full):
        return jax.tree.map(
            lambda r, w: jax.lax.dynamic_update_index_in_dim(r, w.astype(r.dtype), slot, 0),
            ring,
            w_full,
        )

    def ring_put_chunk(ring, slot, w_chunk, c):
        def upd(r, ch):
            buf = jax.lax.dynamic_index_in_dim(r, slot, 0, keepdims=False)
            buf = _put_chunk(buf, ch, cd, chunks, c)
            return jax.lax.dynamic_update_index_in_dim(r, buf, slot, 0)
        return jax.tree.map(upd, ring, w_chunk)

    # prologue: fully gather layers 0..G-2 into slots 0..G-2 (pipeline fill —
    # the paper's ramp).
    for j in range(G - 1):
        ring = ring_put_layer(ring, j, gather(_layer(stacked_ws, min(j, num_layers - 1))))

    def body(state, i):
        c, ring = state
        slot = jax.lax.rem(i, G)
        w_use = jax.tree.map(
            lambda r: jax.lax.dynamic_index_in_dim(r, slot, 0, keepdims=False), ring
        )
        # chunk-gather the window: layer i+k gets chunk (G-1-k), k = 1..G-1.
        for k in range(1, G):
            j = jnp.minimum(i + k, num_layers - 1)
            ch = gather_chunk(_layer(stacked_ws, j), chunks - k if chunks > 1 else 0)
            ring = ring_put_chunk(ring, jax.lax.rem(i + k, G), ch, chunks - k if chunks > 1 else 0)
        c = apply_fn(c, w_use)
        return (c, ring), None

    (carry, _ring), _ = jax.lax.scan(body, (carry_init, ring), jnp.arange(num_layers))
    return carry


def plan_for_layer(
    *,
    layer_bytes: float,
    layer_flops: float,
    mesh: Mesh | None,
    settings: StreamSettings,
    flops_per_s: float = 197e12,
    ici_bytes_per_s: float = 50e9,
) -> StreamPlan:
    """Derive the GPP plan for one layer on the current mesh: the all-gather
    moves (n-1)/n of layer_bytes across the fsdp axis ring of n devices."""
    n = mesh.shape[settings.fsdp_axis] if mesh is not None and not mesh.empty else 1
    gather_bytes = layer_bytes * max(0, n - 1) / max(1, n)
    return plan_stream(
        block_bytes=gather_bytes,
        compute_flops=layer_flops,
        flops_per_s=flops_per_s,
        transfer_bytes_per_s=ici_bytes_per_s,
        max_ring=8,
    )
