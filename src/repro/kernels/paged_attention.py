"""Pallas paged-attention: stream KV blocks through a VMEM ring.

The serving engine's paged read path used to gather every lane's logical KV
sequence out of the shared block pools — `pool[tables]` materializes a
(B, MB*block_size, ...) array in HBM per layer per step, pure un-overlapped
traffic that grows with the table width whether or not the context is live.
This kernel is the generalized ping-pong schedule applied to attention
itself:

  PIM macro           ->  one physical KV block resident in a VMEM ring slot
  weight rewrite      ->  async HBM->VMEM DMA of the NEXT logical block(s)
  compute             ->  the per-block online-softmax flash step
  off-chip bandwidth  ->  HBM DMA bandwidth
  consecutive GeMMs   ->  the flattened (lane, logical-block) grid steps

Block tables and per-lane positions ride in as SCALAR-PREFETCH operands
(`pltpu.PrefetchScalarGridSpec`), so the kernel can compute each DMA's
source — `pool[tables[lane, j]]` — before the grid step that consumes it.
The DMA issue schedule is exactly `gpp_matmul`'s chunk-issue ring
(`_make_chunk_ops` / `_run_chunk_schedule`, factored out in PR 2): with a
ring of G buffers, block j's bytes arrive in C = G-1 chunks during the C
preceding grid steps, so DMA traffic stays flat at one block per flash step
and the compute never waits.  Because the schedule is phrased over global
steps (lane-major), the ring keeps streaming across lane boundaries — lane
b+1's first blocks are in flight while lane b's tail blocks compute.

The gathered (B, MB*bs, ...) sequence is never formed: ragged last blocks,
unmapped table entries (physical block 0, the reserved null block), inactive
lanes parked on block 0, and sliding-window expiry are all handled by the
per-block mask, not by a dense materialized mask.  Blocks wholly outside a
lane's visible range — past its position, or expired behind the sliding
window — are skipped entirely: both the DMA (start AND wait sites evaluate
the same pure predicate over the prefetched scalars, so the semaphore
pairing holds) and the flash update, so per-step HBM traffic is the lane's
LIVE blocks, not the table width.  Unmapped-but-visible entries (only an
inactive lane parked at position 0) read the null block and are masked.

One kernel body serves the whole family:

  GQA / MHA / sliding window   pool_a = k  (nb, bs, KVH, hd)
                               pool_b = v  (nb, bs, KVH, hd)
  MLA (weight-absorbed MQA)    pool_a = c_kv   (nb, bs, kv_lora)
                               pool_b = k_rope (nb, bs, rope_dim)
      with q absorbed through w_uk (models/attention._mla_absorbed_q):
      logits = q_abs . concat(c_kv, k_rope), values = c_kv, and the
      latent output is up-projected through w_uv after the kernel —
      exact same math as the gather path, reassociated.

Queries arrive pre-scaled and pre-transposed as (B, KVH, rep*S, dk) so the
kernel body is nothing but DMA waits, two batched dot_generals, and the
online-softmax update — no in-kernel transposes.  Decode is S=1 with
per-lane positions; a prefill chunk is B=1, S=chunk with ANY start position
(prefix-cache hits resume prefill mid-block — the visibility predicate and
per-(row, slot) mask are position-exact, never block-aligned-assuming);
both compile to the same kernel.

Shared-prefix aliasing contract: with the prefix cache on, one physical
block may appear in SEVERAL lanes' tables (refcounted shares of a common
prompt prefix).  That is safe here by construction — this kernel only ever
READS the pools (`pl.BlockSpec(memory_space=pl.ANY)` inputs, DMA'd into the
VMEM ring; the only output is the attention result).  All pool writes live
in `models.attention._paged_write_span` / `_paged_write_token`, and the
engine asserts before every write that the target blocks are exclusively
owned (`PagedKVCache.assert_writable`): shared blocks are read-only until
`fork_block` copies them out.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.schedule import plan_paged_attn
from repro.kernels.gpp_matmul import (_CompilerParams, _make_chunk_ops,
                                      _run_chunk_schedule,
                                      schedule_lane_events)

NEG_INF = float("-inf")


def paged_lane_events(trace, live_counts: "list[int]", max_blocks: int, *,
                      G: int = 4, block_bytes: int = 0, t0_us: float,
                      dur_us: float, pid: int = 0,
                      max_events: int = 128) -> int:
    """DMA/compute trace lanes for one paged-attention call.

    Replays the kernel's lane-major (B, MB) grid — `live_counts[lane]` live
    logical blocks out of `max_blocks` table entries per lane, exactly what
    the in-kernel `live()` predicate admits for prefix-visible attention —
    through the shared chunk-issue schedule and renders it into `trace`
    over the measured call window.  Dead steps (blocks past a lane's
    position) cost the kernel neither DMA nor compute, so they get zero
    width on the modeled timebase; see
    `kernels.gpp_matmul.schedule_lane_events`."""
    B = len(live_counts)
    steps = B * max_blocks
    if steps <= 0:
        return 0
    G = min(G, steps)
    C = max(1, G - 1)
    return schedule_lane_events(
        trace, num_steps=steps,
        G=G, C=C, t0_us=t0_us, dur_us=dur_us, step_bytes=block_bytes,
        live=lambda s: (s % max_blocks) < live_counts[s // max_blocks],
        pid=pid, max_events=max_events, name="kv")


def _paged_attn_kernel(tables_ref, pos_ref, q_ref, pa_hbm, pb_hbm, out_ref,
                       m_ref, l_ref, acc_ref, ring_a, ring_b, sem_a, sem_b,
                       *, grid_bj: tuple, S: int, kvh: int, rep: int, bs: int,
                       G: int, C: int, window: "int | None", mla: bool,
                       out_dtype):
    """Kernel body; grid = (B, MB), logical-block dim innermost."""
    B, MB = grid_bj
    b, j = pl.program_id(0), pl.program_id(1)
    s = b * MB + j                       # global step, lane-major
    S_total = B * MB

    def live(step):
        """True iff the logical block consumed at `step` overlaps its lane's
        visible key range (pos - window, pos + S - 1].  Pure in the
        prefetched scalars, so the DMA start sites (earlier grid steps) and
        wait sites agree and the semaphore pairing holds; dead blocks cost
        neither DMA nor flash compute."""
        lane = step // MB
        lj = jax.lax.rem(step, MB)
        p = pos_ref[lane]
        ok = lj * bs <= p + (S - 1)
        if window is not None:
            ok &= (lj + 1) * bs - 1 > p - window
        return ok

    def tile_slice(pool):
        def slice_fn(step, lo: int, hi: int):
            lane = step // MB
            phys = tables_ref[lane, jax.lax.rem(step, MB)]
            return pool.at[phys, pl.ds(lo, hi - lo), :]
        return slice_fn

    start_a, wait_a = _make_chunk_ops(pa_hbm, ring_a, sem_a, G, C, bs,
                                      tile_slice(pa_hbm))
    start_b, wait_b = _make_chunk_ops(pb_hbm, ring_b, sem_b, G, C, bs,
                                      tile_slice(pb_hbm))

    def start_chunk(step, c):
        @pl.when(live(step))
        def _():
            start_a(step, c)
            start_b(step, c)

    def wait_chunk(step, c):
        @pl.when(live(step))
        def _():
            wait_a(step, c)
            wait_b(step, c)

    _run_chunk_schedule(s, S_total, G, C, start_chunk, wait_chunk)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(live(s))
    def _flash_step():
        slot = jax.lax.rem(s, G)
        ka = ring_a[slot]                # (bs, Fa)
        kb = ring_b[slot]                # (bs, Fb)
        if mla:
            # weight-absorbed MLA is MQA: one shared key = concat(latent,
            # rope), values are the latent — no second value ring needed.
            k3 = jnp.concatenate([ka, kb], axis=-1)[:, None, :]  # (bs, 1, dk)
            v3 = ka[:, None, :]                                  # (bs, 1, dv)
        else:
            k3 = ka.reshape(bs, kvh, -1)
            v3 = kb.reshape(bs, kvh, -1)

        # (KVH, rep*S, bs) logits for this block, f32 accumulation.
        qr = q_ref[0]                    # (KVH, rep*S, dk), pre-scaled
        logits = jax.lax.dot_general(
            qr, k3,
            dimension_numbers=(((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32,
        )

        # mask per (query-row, key-slot): flattened row i is (r, s_) with
        # s_ = i % S, so qpos = pos[lane] + s_; key slot t holds absolute
        # position j*bs + t.  Ragged tails, null-block reads, and window
        # expiry all fall out of this one predicate.
        rS = rep * S
        srow = jax.lax.broadcasted_iota(jnp.int32, (rS, bs), 0) % S
        qpos = pos_ref[b] + srow
        kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (rS, bs), 1)
        valid = kpos <= qpos
        if window is not None:
            valid &= kpos > qpos - window
        logits = jnp.where(valid[None], logits, NEG_INF)

        # online softmax (the _sdpa_kv_chunked recurrence, per KV block)
        m, l, acc = m_ref[...], l_ref[...], acc_ref[...]
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(logits - m_safe[..., None])
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        pv = jax.lax.dot_general(
            p.astype(v3.dtype), v3,
            dimension_numbers=(((2,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new
        l_ref[...] = l * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc * corr[..., None] + pv

    @pl.when(j == MB - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        out_ref[0] = out.astype(out_dtype)


def paged_attention(
    q: jnp.ndarray,
    pool_a: jnp.ndarray,
    pool_b: jnp.ndarray,
    tables: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    num_kv_heads: int,
    scale: float,
    window: "int | None" = None,
    mla: bool = False,
    num_bufs: "int | None" = None,
    vmem_budget: "int | None" = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Block-table paged attention over shared KV pools.

    Args:
      q: (B, S, H, dk) queries.  Decode: S == 1 with per-lane positions;
         prefill chunk: B == 1 with any (not necessarily block-aligned)
         start position.
      pool_a / pool_b: shared physical pools, leading dims (nb, bs, ...).
         GQA: k / v with trailing (KVH, hd).  MLA: c_kv (nb, bs, kv_lora) /
         k_rope (nb, bs, rope_dim) with `mla=True` and q already absorbed
         through w_uk (dk = kv_lora + rope_dim); returns the latent output
         (B, S, H, kv_lora) for the caller to up-project through w_uv.
      tables: (B, MB) int32 block table — entry 0 is the reserved null block
         (unmapped / inactive lanes), masked by construction.
      positions: (B,) int32 — each lane's query start position (decode: the
         token's position; prefill: chunk start).
      num_kv_heads: KVH for the GQA pools (ignored under `mla`).
      scale: softmax scale, folded into q before the kernel.
      window: sliding-window size; expiry is masked per block.
      num_bufs: KV-block ring depth G; None plans it via
         `core.schedule.plan_paged_attn` (VMEM budget + TimingCache rates).
      interpret: run in interpret mode (CPU validation).

    Returns: (B, S, H, dv) attention output in q.dtype (dv = hd, or kv_lora
    under `mla`).
    """
    B, S, H, dk = q.shape
    Bt, MB = tables.shape
    if Bt != B or positions.shape != (B,):
        raise ValueError(
            f"tables {tables.shape} / positions {positions.shape} do not "
            f"match q batch {B}")
    if pool_a.shape[:2] != pool_b.shape[:2]:
        raise ValueError(
            f"pool block dims differ: {pool_a.shape} vs {pool_b.shape}")
    nb, bs = pool_a.shape[:2]
    kvh = 1 if mla else num_kv_heads
    if H % kvh:
        raise ValueError(f"{H} heads not divisible by {kvh} kv heads")
    rep = H // kvh
    # flatten trailing dims: one 3-D (nb, bs, F) layout per pool, so the ring
    # DMA helpers see the same (rows, lanes) tiles as the matmul kernel.
    pa = pool_a.reshape(nb, bs, -1)
    pb = pool_b.reshape(nb, bs, -1)
    Fa, Fb = pa.shape[-1], pb.shape[-1]
    if mla:
        dv = Fa
        if dk != Fa + Fb:
            raise ValueError(
                f"mla q dk={dk} != kv_lora {Fa} + rope {Fb}")
    else:
        dv = pool_b.shape[-1]
        if Fa != kvh * dk:
            raise ValueError(
                f"k pool trailing {pool_a.shape[2:]} does not match "
                f"{kvh} kv heads x head_dim {dk}")

    kdtype = pool_a.dtype
    # pre-scale and pre-transpose q outside the kernel: (B, S, KVH, rep, dk)
    # -> (B, KVH, rep*S, dk), mirroring _sdpa's q-scaling dtype discipline.
    qr = (q.astype(jnp.float32) * scale).astype(kdtype)
    q2 = (qr.reshape(B, S, kvh, rep, dk)
            .transpose(0, 2, 3, 1, 4)
            .reshape(B, kvh, rep * S, dk))

    rS = rep * S
    itemsize = jnp.dtype(kdtype).itemsize
    fixed = (kvh * rS * (dk + dv) * itemsize      # queries + output block
             + kvh * rS * (dv + 2) * 4)           # f32 acc + m + l
    plan_kw = dict(vmem_budget=vmem_budget) if vmem_budget is not None else {}
    plan = plan_paged_attn(
        block_bytes=bs * (Fa + Fb) * itemsize,
        compute_flops=2.0 * rS * bs * (dk + dv) * kvh,
        fixed_bytes=fixed,
        num_bufs=num_bufs,
        **plan_kw,
    )
    G = min(plan.num_bufs, max(1, B * MB))
    C = max(1, min(G - 1, bs))

    kernel = functools.partial(
        _paged_attn_kernel, grid_bj=(B, MB), S=S, kvh=kvh, rep=rep, bs=bs,
        G=G, C=C, window=window, mla=mla, out_dtype=q.dtype,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,           # tables, positions
        grid=(B, MB),
        in_specs=[
            pl.BlockSpec((1, kvh, rS, dk), lambda b, j, *_: (b, 0, 0, 0)),
            pl.BlockSpec(memory_space=pl.ANY),      # pool_a: stays in HBM
            pl.BlockSpec(memory_space=pl.ANY),      # pool_b: stays in HBM
        ],
        out_specs=pl.BlockSpec((1, kvh, rS, dv), lambda b, j, *_: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kvh, rS), jnp.float32),     # running max
            pltpu.VMEM((kvh, rS), jnp.float32),     # running denominator
            pltpu.VMEM((kvh, rS, dv), jnp.float32),  # f32 accumulator
            pltpu.VMEM((G, bs, Fa), kdtype),        # k / c_kv block ring
            pltpu.VMEM((G, bs, Fb), kdtype),        # v / k_rope block ring
            pltpu.SemaphoreType.DMA((G,)),
            pltpu.SemaphoreType.DMA((G,)),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, kvh, rS, dv), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",) * 2,  # sequential grid
        ),
        interpret=interpret,
    )(tables.astype(jnp.int32), positions.astype(jnp.int32), q2, pa, pb)
    return (out.reshape(B, kvh, rep, S, dv)
               .transpose(0, 3, 1, 2, 4)
               .reshape(B, S, H, dv))
