"""Jit'd public wrappers for the Pallas kernels.

`streamed_matmul` picks the ring depth from the same GPP planner that the
paper's analytic model validates (`repro.core.schedule.plan_stream`), using
TPU v5e constants: a (K, bn) bf16 tile moves 2*K*bn bytes at ~819 GB/s HBM
while the MXU computes 2*M*K*bn flops at ~197 TFLOP/s, so
t_dma/t_compute = 197e12*2 / (819e9 * 2*M) ≈ 120/M — small M (the paper's
small-n_in regime) is exactly where deep rings win.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.schedule import plan_stream
from repro.kernels.gpp_matmul import gpp_matmul

HBM_BYTES_PER_S = 819e9
PEAK_FLOPS = 197e12


def plan_ring_depth(M: int, K: int, block_n: int, dtype=jnp.bfloat16, max_ring: int = 8) -> int:
    """Ring depth G = ceil(t_dma / t_compute) + 1 for one weight tile."""
    itemsize = jnp.dtype(dtype).itemsize
    plan = plan_stream(
        block_bytes=K * block_n * itemsize,
        compute_flops=2.0 * M * K * block_n,
        flops_per_s=PEAK_FLOPS,
        transfer_bytes_per_s=HBM_BYTES_PER_S,
        max_ring=max_ring,
    )
    return plan.ring_depth


@functools.partial(jax.jit, static_argnames=("block_n", "num_bufs", "interpret"))
def streamed_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    block_n: int = 256,
    num_bufs: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """y = x @ w with HBM-streamed weights under the GPP DMA schedule."""
    if num_bufs is None:
        num_bufs = plan_ring_depth(x.shape[0], x.shape[1], block_n, x.dtype)
    return gpp_matmul(x, w, block_n=block_n, num_bufs=num_bufs, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_n", "num_bufs", "interpret"))
def streamed_gemm_sequence(
    x: jnp.ndarray,
    ws: jnp.ndarray,
    *,
    block_n: int = 256,
    num_bufs: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """The paper's BLAS workload: consecutive GeMMs ys[r] = x @ ws[r] with
    every round's weights streamed from HBM.  The round dimension is folded
    into the streamed tile stream, so the ring pipelines *across* GeMMs just
    like macros pipeline across consecutive layers."""
    R, K, N = ws.shape
    w_flat = jnp.transpose(ws, (1, 0, 2)).reshape(K, R * N)
    if num_bufs is None:
        num_bufs = plan_ring_depth(x.shape[0], K, block_n, x.dtype)
    y = gpp_matmul(x, w_flat, block_n=block_n, num_bufs=num_bufs, interpret=interpret)
    M = x.shape[0]
    return jnp.transpose(y.reshape(M, R, N), (1, 0, 2))
