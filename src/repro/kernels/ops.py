"""Jit'd public wrappers for the Pallas kernels.

`streamed_matmul` picks the ring depth from the same GPP planner that the
paper's analytic model validates (`repro.core.schedule.plan_stream`), using
TPU v5e constants: a (block_k, bn) bf16 tile moves block_k*bn*2 bytes at
~819 GB/s HBM while the MXU computes 2*M*block_k*bn flops at ~197 TFLOP/s,
so t_dma/t_compute ≈ 120/M for bf16 — small M (the paper's small-n_in
regime) is exactly where deep rings win.

`dense` is the single model-facing matmul entry point for the whole model
zoo.  Routing table (who calls it, with what weight layout):

  models/layers.py   mlp w_up/w_gate/w_down        (D, F) 2-D weights
  models/attention.py  gqa/mha q/k/v  (D, H, hd)   contract_dims=1
                       o-proj         (H, hd, D)   contract_dims=2
                       MLA w_dq/w_dkv (D, R)       2-D
                           w_uq/w_uk/w_uv (R, H, hd)  contract_dims=1
                           w_o        (H, hd, D)   contract_dims=2
                       cross-attn q/k/v/o          as gqa
  models/ssm.py        w_in/w_bc/w_dt/w_out        2-D
  models/xlstm.py      mlstm q/k/v (D, H, hd), w_o (H, hd, D), gates,
                       slstm z/i/f/og/out          2-D
  models/moe.py        router/shared experts via `dense`; routed expert
                       FFNs via `dense_grouped` (E, D, F) batched weights

The einsum-shaped projection adapter: leading dims of x are flattened, the
last `contract_dims` dims of x contract against the first `contract_dims`
dims of w (reshaped to 2-D), and both are restored on the way out — so
`dhk`/`hkd`-style projection tensors stream through the same GPP schedule
as plain 2-D matmuls.  The matmul routes either through the streaming
Pallas kernel (TPU backend, weight large enough to be worth streaming) or
through the fused-epilogue jnp reference (CPU / tiny weights).  The "ref"
mode reproduces plain `act(x @ w)` math bit-for-bit so existing model
numerics are unchanged when the kernel is off.

`dense_grouped` is the MoE batched-expert variant: (E, C, D) @ (E, D, F)
with the expert axis as the outermost ring dimension of the streaming
schedule, so each expert's weights cross the HBM link exactly once per
step and the ring pipelines across experts.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core.schedule import HBM_BYTES_PER_S, PEAK_FLOPS, plan_stream
from repro.kernels.gpp_matmul import _ACTIVATIONS, gpp_matmul, gpp_matmul_grouped
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ref import dense_grouped_ref, dense_ref, paged_attn_ref

# below this weight size the DMA pipeline cannot beat a resident matmul
DENSE_KERNEL_MIN_BYTES = 1 * 1024 * 1024

# shared by `dense` and `dense_grouped` (the grouped path accepts the same
# four modes; "kernel"/"interpret" route through gpp_matmul_grouped)
DENSE_MODES = ("auto", "ref", "kernel", "interpret")

# paged-attention read-path routing (cfg.paged_attn_kernel): "pallas" is the
# compiled block-table kernel, "interpret" the same kernel on the CPU
# interpreter, "ref" the exact gather+_sdpa math the serving engine shipped
# with, "auto" picks pallas on TPU and ref elsewhere (like `dense`'s auto).
PAGED_ATTN_MODES = ("auto", "ref", "pallas", "interpret")


def plan_ring_depth(M: int, K: int, block_n: int, dtype=jnp.bfloat16, max_ring: int = 8) -> int:
    """Ring depth G = ceil(t_dma / t_compute) + 1 for one weight tile."""
    itemsize = jnp.dtype(dtype).itemsize
    plan = plan_stream(
        block_bytes=K * block_n * itemsize,
        compute_flops=2.0 * M * K * block_n,
        flops_per_s=PEAK_FLOPS,
        transfer_bytes_per_s=HBM_BYTES_PER_S,
        max_ring=max_ring,
    )
    return plan.ring_depth


@functools.partial(jax.jit, static_argnames=(
    "activation", "block_m", "block_n", "block_k", "num_bufs", "interpret"))
def streamed_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    bias: jnp.ndarray | None = None,
    w_scale: jnp.ndarray | None = None,
    activation: str | None = None,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    num_bufs: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """y = epilogue(x @ w) with HBM-streamed weights under the GPP DMA
    schedule, tiled over M/N/K by the VMEM-budget planner."""
    return gpp_matmul(
        x, w, bias=bias, w_scale=w_scale, activation=activation,
        block_m=block_m, block_n=block_n, block_k=block_k,
        num_bufs=num_bufs, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_n", "num_bufs", "interpret"))
def streamed_gemm_sequence(
    x: jnp.ndarray,
    ws: jnp.ndarray,
    *,
    block_n: int = 256,
    num_bufs: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """The paper's BLAS workload: consecutive GeMMs ys[r] = x @ ws[r] with
    every round's weights streamed from HBM.  The round dimension is folded
    into the streamed tile stream, so the ring pipelines *across* GeMMs just
    like macros pipeline across consecutive layers."""
    R, K, N = ws.shape
    w_flat = jnp.transpose(ws, (1, 0, 2)).reshape(K, R * N)
    if num_bufs is None:
        num_bufs = plan_ring_depth(x.shape[0], K, block_n, x.dtype)
    y = gpp_matmul(x, w_flat, block_n=block_n, num_bufs=num_bufs, interpret=interpret)
    M = x.shape[0]
    return jnp.transpose(y.reshape(M, R, N), (1, 0, 2))


def _ambient_mesh_active() -> bool:
    """True when an ambient SPMD mesh is set: `pallas_call` cannot be
    partitioned by GSPMD, so auto-mode must not route sharded global arrays
    into the kernel — XLA would all-gather the full weight onto every device
    (the exact traffic blowup the streaming path exists to avoid).  Callers
    that hold per-rank local arrays (inside shard_map) can still opt in with
    an explicit mode="kernel"."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        return mesh is not None and not mesh.empty
    except Exception:  # noqa: BLE001 — older jax: no ambient-mesh API
        return False


def _targets_tpu(*arrays) -> bool:
    """Best-effort check that the computation will land on TPU: committed
    concrete arrays reveal their devices (every inspectable array must be on
    TPU); tracers (under jit) don't, so we fall back to the process default
    backend.  Work explicitly pinned to CPU inside a jit on a TPU host can
    still mis-route — pass mode="ref" there."""
    saw_devices = False
    for a in arrays:
        devices = getattr(a, "devices", None)
        if callable(devices):
            try:
                if not all(d.platform == "tpu" for d in devices()):
                    return False
                saw_devices = True
            except Exception:
                continue
    return saw_devices or jax.default_backend() == "tpu"


def _resolve_auto_mode(x, w) -> str:
    """The single auto-routing policy for `dense` and `dense_grouped`:
    kernel on TPU when w is in the streaming regime AND no ambient SPMD
    mesh would have to all-gather it into the (unpartitionable) pallas_call;
    else the bit-identical ref path."""
    w_bytes = w.size * w.dtype.itemsize
    return ("kernel" if _targets_tpu(x, w)
            and w_bytes >= DENSE_KERNEL_MIN_BYTES
            and not _ambient_mesh_active() else "ref")


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _dense_kernel(activation, interpret, x2, w, bias, w_scale):
    """Kernel-path forward with a ref-math VJP: the Pallas kernel has no AD
    rule, so backward recomputes through the fused-epilogue oracle
    (`dense_ref`, same f32 math the kernel implements) — training under
    mode="auto"/"kernel" gets standard XLA matmul gradients while the
    forward keeps the streaming schedule."""
    return gpp_matmul(x2, w, bias=bias, w_scale=w_scale,
                      activation=activation, interpret=interpret)


def _dense_kernel_fwd(activation, interpret, x2, w, bias, w_scale):
    y = _dense_kernel(activation, interpret, x2, w, bias, w_scale)
    return y, (x2, w, bias, w_scale)


def _dense_kernel_bwd(activation, interpret, res, g):
    x2, w, bias, w_scale = res
    _, pullback = jax.vjp(
        lambda xx, ww, bb, ss: dense_ref(xx, ww, bias=bb, w_scale=ss,
                                         activation=activation),
        x2, w, bias, w_scale)
    return pullback(g)


_dense_kernel.defvjp(_dense_kernel_fwd, _dense_kernel_bwd)


def _dense_ref_path(x2: jnp.ndarray, w: jnp.ndarray, bias, activation):
    """Exact pre-kernel model math: act(x @ w [+ bias]) in the ambient dtype
    (no f32 round trip), so "ref" routing leaves existing models untouched."""
    y = x2 @ w
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return _ACTIVATIONS[activation](y)


def dense(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    bias: jnp.ndarray | None = None,
    w_scale: jnp.ndarray | None = None,
    activation: str | None = None,
    mode: str = "auto",
    contract_dims: int = 1,
) -> jnp.ndarray:
    """act(x @ w [* w_scale] [+ bias]) over arbitrary leading dims of x.

    The projection adapter generalizes the matmul to einsum-shaped weights:
    the last `contract_dims` dims of x contract against the first
    `contract_dims` dims of w, and w's remaining dims shape the output —
    e.g. q-proj `bsd,dhk->bshk` is `dense(x, w_q)`, o-proj `bshk,hkd->bsd`
    is `dense(out, w_o, contract_dims=2)`.  bias (if any) must match w's
    output dims.

    mode:
      auto       kernel on TPU when w is at least DENSE_KERNEL_MIN_BYTES
                 (the streaming regime), else ref
      kernel     always the Pallas GPP kernel (compiled)
      interpret  the Pallas kernel in interpret mode (CPU validation)
      ref        fused jnp fallback (identical math to the pre-kernel models)
    """
    if mode not in DENSE_MODES:
        raise ValueError(f"dense mode must be one of {DENSE_MODES}, got {mode!r}")
    if activation not in _ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    if not 1 <= contract_dims <= min(x.ndim, w.ndim):
        raise ValueError(
            f"contract_dims={contract_dims} invalid for x{x.shape} @ w{w.shape}")
    cshape = w.shape[:contract_dims]
    if x.shape[-contract_dims:] != cshape:
        raise ValueError(
            f"contraction mismatch: x{x.shape} trailing dims vs w{w.shape} "
            f"leading dims (contract_dims={contract_dims})")
    out_dims = w.shape[contract_dims:]
    Kf = math.prod(cshape)
    Nf = math.prod(out_dims)
    lead = x.shape[:x.ndim - contract_dims]
    x2 = x.reshape(-1, Kf)
    w2 = w.reshape(Kf, Nf)
    if bias is not None:
        bias = bias.reshape(Nf)
    if mode == "auto":
        mode = _resolve_auto_mode(x, w)
    if mode == "ref":
        if w_scale is not None:
            w2 = (w2.astype(jnp.float32)
                  * jnp.asarray(w_scale, jnp.float32).reshape(1, -1)).astype(x.dtype)
        y2 = _dense_ref_path(x2, w2, bias, activation)
    else:
        y2 = _dense_kernel(activation, mode == "interpret", x2, w2, bias, w_scale)
    return y2.reshape(*lead, *out_dims)


# ---------------------------------------------------------------------------
# paged-attention entry point (serving read path)
# ---------------------------------------------------------------------------

def resolve_paged_attn_mode(mode: str, *arrays) -> str:
    """Resolve "auto" for the paged-attention read path: the Pallas kernel on
    TPU (pallas_call is not GSPMD-partitionable, so an ambient mesh falls
    back, mirroring `dense`'s auto policy), the exact gather math elsewhere.
    Returns one of "ref" | "pallas" | "interpret"."""
    if mode not in PAGED_ATTN_MODES:
        raise ValueError(
            f"paged_attn mode must be one of {PAGED_ATTN_MODES}, got {mode!r}")
    if mode != "auto":
        return mode
    return ("pallas" if _targets_tpu(*arrays) and not _ambient_mesh_active()
            else "ref")


def paged_attn(
    q: jnp.ndarray,
    pool_a: jnp.ndarray,
    pool_b: jnp.ndarray,
    tables: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    num_kv_heads: int,
    scale: float,
    window: "int | None" = None,
    mla: bool = False,
    mode: str = "auto",
    num_bufs: "int | None" = None,
) -> jnp.ndarray:
    """Paged attention over shared block pools, routed like `dense`.

    q: (B, S, H, dk); pool_a/pool_b: (nb, bs, ...) physical pools;
    tables: (B, MB) int32 block table (0 = reserved null block);
    positions: (B,) int32 per-lane query start positions.

    GQA: pools are k/v, dk = head_dim.  MLA (`mla=True`): pools are
    c_kv/k_rope, q is already absorbed through w_uk (dk = kv_lora + rope),
    and the return value is the latent output for the caller to up-project.

    mode:
      auto       pallas on TPU, else ref
      pallas     the streaming Pallas kernel (compiled)
      interpret  the same kernel on the interpreter (CPU validation)
      ref        gather through the tables + exact `_sdpa` math — the
                 pre-kernel serving read path, bit-for-bit
    """
    mode = resolve_paged_attn_mode(mode, q, pool_a, pool_b)
    if mode == "ref":
        return paged_attn_ref(q, pool_a, pool_b, tables, positions,
                              num_kv_heads=num_kv_heads, scale=scale,
                              window=window, mla=mla)
    return paged_attention(q, pool_a, pool_b, tables, positions,
                           num_kv_heads=num_kv_heads, scale=scale,
                           window=window, mla=mla, num_bufs=num_bufs,
                           interpret=mode == "interpret")


# ---------------------------------------------------------------------------
# grouped (batched-expert) entry point
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _dense_grouped_kernel(activation, interpret, x3, w, bias, w_scale):
    """Grouped kernel-path forward with a ref-math VJP (see `_dense_kernel`):
    backward recomputes through `dense_grouped_ref`, the same f32 math the
    grouped kernel implements."""
    return gpp_matmul_grouped(x3, w, bias=bias, w_scale=w_scale,
                              activation=activation, interpret=interpret)


def _dense_grouped_kernel_fwd(activation, interpret, x3, w, bias, w_scale):
    y = _dense_grouped_kernel(activation, interpret, x3, w, bias, w_scale)
    return y, (x3, w, bias, w_scale)


def _dense_grouped_kernel_bwd(activation, interpret, res, g):
    x3, w, bias, w_scale = res
    _, pullback = jax.vjp(
        lambda xx, ww, bb, ss: dense_grouped_ref(xx, ww, bias=bb, w_scale=ss,
                                                 activation=activation),
        x3, w, bias, w_scale)
    return pullback(g)


_dense_grouped_kernel.defvjp(_dense_grouped_kernel_fwd, _dense_grouped_kernel_bwd)


def dense_grouped(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    bias: jnp.ndarray | None = None,
    w_scale: jnp.ndarray | None = None,
    activation: str | None = None,
    mode: str = "auto",
) -> jnp.ndarray:
    """Per-expert act(x[e] @ w[e] [* w_scale[e]] [+ bias[e]]):
    (E, C, D) @ (E, D, F).

    The MoE companion to `dense`: the streaming plan treats the expert axis
    as the outermost ring dimension, so expert weights stream from HBM once
    per step and the ring pipelines across experts (the paper's
    consecutive-GeMM workload with per-round activations).  `w_scale`
    (scalar, (E,), or (E, F)) is the int8 dequant path: expert weights
    stream raw and the scale folds into the fused epilogue, mirroring the
    flat kernel.  Modes as in `dense`; "ref" reproduces the models' plain
    batched-einsum math bit-for-bit (dequant pre-scales the weights, like
    `dense`'s ref path).
    """
    if mode not in DENSE_MODES:
        raise ValueError(f"dense mode must be one of {DENSE_MODES}, got {mode!r}")
    if activation not in _ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    if x.ndim != 3 or w.ndim != 3:
        raise ValueError(f"dense_grouped wants (E,C,D) @ (E,D,F), "
                         f"got x{x.shape} w{w.shape}")
    if x.shape[0] != w.shape[0] or x.shape[2] != w.shape[1]:
        raise ValueError(f"grouped shape mismatch: x{x.shape} @ w{w.shape}")
    if mode == "auto":
        mode = _resolve_auto_mode(x, w)
    if mode == "ref":
        if w_scale is not None:
            sc = jnp.asarray(w_scale, jnp.float32)
            sc = sc if sc.ndim == 0 else sc.reshape(w.shape[0], 1, -1)
            w = (w.astype(jnp.float32) * sc).astype(x.dtype)
        y = jnp.einsum("ecd,edf->ecf", x, w)
        if bias is not None:
            y = y + bias[:, None, :].astype(y.dtype)
        return _ACTIVATIONS[activation](y)
    return _dense_grouped_kernel(activation, mode == "interpret", x, w, bias,
                                 w_scale)
