"""Jit'd public wrappers for the Pallas kernels.

`streamed_matmul` picks the ring depth from the same GPP planner that the
paper's analytic model validates (`repro.core.schedule.plan_stream`), using
TPU v5e constants: a (block_k, bn) bf16 tile moves block_k*bn*2 bytes at
~819 GB/s HBM while the MXU computes 2*M*block_k*bn flops at ~197 TFLOP/s,
so t_dma/t_compute ≈ 120/M for bf16 — small M (the paper's small-n_in
regime) is exactly where deep rings win.

`dense` is the model-facing entry point: it flattens leading dims, routes the
matmul either through the streaming Pallas kernel (TPU backend, weight large
enough to be worth streaming) or through the fused-epilogue jnp reference
(CPU / tiny weights), and restores the leading dims.  The "ref" mode
reproduces plain `act(x @ w)` math bit-for-bit so existing model numerics
are unchanged when the kernel is off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.schedule import HBM_BYTES_PER_S, PEAK_FLOPS, plan_stream
from repro.kernels.gpp_matmul import _ACTIVATIONS, gpp_matmul
from repro.kernels.ref import dense_ref

# below this weight size the DMA pipeline cannot beat a resident matmul
DENSE_KERNEL_MIN_BYTES = 1 * 1024 * 1024

DENSE_MODES = ("auto", "ref", "kernel", "interpret")


def plan_ring_depth(M: int, K: int, block_n: int, dtype=jnp.bfloat16, max_ring: int = 8) -> int:
    """Ring depth G = ceil(t_dma / t_compute) + 1 for one weight tile."""
    itemsize = jnp.dtype(dtype).itemsize
    plan = plan_stream(
        block_bytes=K * block_n * itemsize,
        compute_flops=2.0 * M * K * block_n,
        flops_per_s=PEAK_FLOPS,
        transfer_bytes_per_s=HBM_BYTES_PER_S,
        max_ring=max_ring,
    )
    return plan.ring_depth


@functools.partial(jax.jit, static_argnames=(
    "activation", "block_m", "block_n", "block_k", "num_bufs", "interpret"))
def streamed_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    bias: jnp.ndarray | None = None,
    w_scale: jnp.ndarray | None = None,
    activation: str | None = None,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    num_bufs: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """y = epilogue(x @ w) with HBM-streamed weights under the GPP DMA
    schedule, tiled over M/N/K by the VMEM-budget planner."""
    return gpp_matmul(
        x, w, bias=bias, w_scale=w_scale, activation=activation,
        block_m=block_m, block_n=block_n, block_k=block_k,
        num_bufs=num_bufs, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_n", "num_bufs", "interpret"))
def streamed_gemm_sequence(
    x: jnp.ndarray,
    ws: jnp.ndarray,
    *,
    block_n: int = 256,
    num_bufs: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """The paper's BLAS workload: consecutive GeMMs ys[r] = x @ ws[r] with
    every round's weights streamed from HBM.  The round dimension is folded
    into the streamed tile stream, so the ring pipelines *across* GeMMs just
    like macros pipeline across consecutive layers."""
    R, K, N = ws.shape
    w_flat = jnp.transpose(ws, (1, 0, 2)).reshape(K, R * N)
    if num_bufs is None:
        num_bufs = plan_ring_depth(x.shape[0], K, block_n, x.dtype)
    y = gpp_matmul(x, w_flat, block_n=block_n, num_bufs=num_bufs, interpret=interpret)
    M = x.shape[0]
    return jnp.transpose(y.reshape(M, R, N), (1, 0, 2))


def _targets_tpu(*arrays) -> bool:
    """Best-effort check that the computation will land on TPU: committed
    concrete arrays reveal their devices (every inspectable array must be on
    TPU); tracers (under jit) don't, so we fall back to the process default
    backend.  Work explicitly pinned to CPU inside a jit on a TPU host can
    still mis-route — pass mode="ref" there."""
    saw_devices = False
    for a in arrays:
        devices = getattr(a, "devices", None)
        if callable(devices):
            try:
                if not all(d.platform == "tpu" for d in devices()):
                    return False
                saw_devices = True
            except Exception:
                continue
    return saw_devices or jax.default_backend() == "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _dense_kernel(activation, interpret, x2, w, bias, w_scale):
    """Kernel-path forward with a ref-math VJP: the Pallas kernel has no AD
    rule, so backward recomputes through the fused-epilogue oracle
    (`dense_ref`, same f32 math the kernel implements) — training under
    mode="auto"/"kernel" gets standard XLA matmul gradients while the
    forward keeps the streaming schedule."""
    return gpp_matmul(x2, w, bias=bias, w_scale=w_scale,
                      activation=activation, interpret=interpret)


def _dense_kernel_fwd(activation, interpret, x2, w, bias, w_scale):
    y = _dense_kernel(activation, interpret, x2, w, bias, w_scale)
    return y, (x2, w, bias, w_scale)


def _dense_kernel_bwd(activation, interpret, res, g):
    x2, w, bias, w_scale = res
    _, pullback = jax.vjp(
        lambda xx, ww, bb, ss: dense_ref(xx, ww, bias=bb, w_scale=ss,
                                         activation=activation),
        x2, w, bias, w_scale)
    return pullback(g)


_dense_kernel.defvjp(_dense_kernel_fwd, _dense_kernel_bwd)


def _dense_ref_path(x2: jnp.ndarray, w: jnp.ndarray, bias, activation):
    """Exact pre-kernel model math: act(x @ w [+ bias]) in the ambient dtype
    (no f32 round trip), so "ref" routing leaves existing models untouched."""
    y = x2 @ w
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return _ACTIVATIONS[activation](y)


def dense(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    bias: jnp.ndarray | None = None,
    w_scale: jnp.ndarray | None = None,
    activation: str | None = None,
    mode: str = "auto",
) -> jnp.ndarray:
    """act(x @ w [* w_scale] [+ bias]) over arbitrary leading dims of x.

    mode:
      auto       kernel on TPU when w is at least DENSE_KERNEL_MIN_BYTES
                 (the streaming regime), else ref
      kernel     always the Pallas GPP kernel (compiled)
      interpret  the Pallas kernel in interpret mode (CPU validation)
      ref        fused jnp fallback (identical math to the pre-kernel models)
    """
    if mode not in DENSE_MODES:
        raise ValueError(f"dense mode must be one of {DENSE_MODES}, got {mode!r}")
    if activation not in _ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if mode == "auto":
        w_bytes = w.size * w.dtype.itemsize
        mode = ("kernel" if _targets_tpu(x, w)
                and w_bytes >= DENSE_KERNEL_MIN_BYTES else "ref")
    if mode == "ref":
        if w_scale is not None:
            w = (w.astype(jnp.float32)
                 * jnp.asarray(w_scale, jnp.float32).reshape(1, -1)).astype(x.dtype)
        y2 = _dense_ref_path(x2, w, bias, activation)
    else:
        y2 = _dense_kernel(activation, mode == "interpret", x2, w, bias, w_scale)
    return y2.reshape(*lead, w.shape[-1])
