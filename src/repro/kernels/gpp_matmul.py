"""Generalized ping-pong streaming matmul — the paper's GeMM engine on TPU.

y[M, N] = epilogue(x[M, K] @ W[K, N]) where W is too large to be VMEM-resident
and streams from HBM ("off-chip") while the MXU computes — the PIM
concurrent-write/compute problem mapped to the TPU memory hierarchy
(DESIGN.md §2.1), now on a 3-D (num_m, num_n, num_k) grid so arbitrary M/K/N
fit in VMEM:

  PIM macro           ->  one (block_k, block_n) weight tile resident in VMEM
  weight rewrite      ->  async HBM->VMEM DMA into a ring slot
  n_in input vectors  ->  the block_m rows matmul'd against the resident tile
  off-chip bandwidth  ->  HBM DMA bandwidth
  consecutive GeMMs   ->  the flattened sequence of grid steps: k innermost
                          (f32 accumulator carried across), then n, then m

Strategies (selected by `num_bufs`):
  num_bufs == 1   in-situ: DMA tile for step s, wait, compute (bursty, stalls)
  num_bufs == 2   naive ping-pong: classic double buffering — whole-tile DMA
                  for step s+1 issued while computing step s
  num_bufs >= 3   generalized ping-pong: ring of G buffers; while computing
                  step s, issue ONE CHUNK (1/C of a tile, C = G-1) for each of
                  the C upcoming steps, so DMA traffic is flat at exactly one
                  tile per compute step and the MXU never waits even when
                  t_dma > t_compute.

The chunk schedule is the seed 1-D schedule re-derived over *global grid
steps* instead of N-tiles: with S = num_m*num_n*num_k sequential steps, the
weight tile needed at step s is tile(s) = s mod (num_n*num_k) (column tile
n = tile//num_k, K-tile k = tile mod num_k), its ring slot is s mod G, and
chunk c of step s's tile is issued at step s-C+c (steps < 0 fold into the
step-0 pipeline-fill prologue).  Because the schedule is phrased in steps,
the one-tile-per-step flat-bandwidth invariant holds across k-loop, n-loop
and m-loop boundaries alike — including the ragged final tiles, which the
wrapper zero-pads to full blocks.  Coverage proof:
tests/test_kernels.py::TestSchedule.

Grid steps on TPU run sequentially on one core ("arbitrary" dimension
semantics), so DMA state (semaphore signals) persists across steps — the
standard Pallas manual-multibuffering pattern.  Chunks split the block_k
(sublane) dimension so each DMA keeps full 128-lane rows.

Epilogue (fused into the last K step, before the output store, all in f32):
  optional per-column dequant scale (int8/bf16 weights are DMA'd raw and
  widened in-kernel), optional bias add, optional activation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.schedule import plan_matmul_tiles

# renamed CompilerParams -> TPUCompilerParams across jax versions
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

_ACTIVATIONS = {
    None: lambda x: x,
    "none": lambda x: x,
    "relu": lambda x: jnp.maximum(x, 0.0),
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "tanh": jnp.tanh,
    "sigmoid": jax.nn.sigmoid,
}


def _chunk_bounds(K: int, chunks: int, c: int) -> tuple[int, int]:
    base = K // chunks
    lo = c * base
    hi = K if c == chunks - 1 else lo + base
    return lo, hi


def chunk_issue_schedule(num_steps: int, G: int,
                         C: int) -> "dict[tuple[int, int], list[int]]":
    """Pure-Python replay of the kernel's DMA issue schedule.

    Returns {(step, chunk): [issue_steps]} — the steps at which chunk `chunk`
    of the weight tile consumed at `step` is DMA'd.  Mirrors `_gpp_kernel`'s
    loops one-for-one; the schedule tests assert every (step, chunk) appears
    exactly once, at or before its consuming step.
    """
    issued: dict[tuple[int, int], list[int]] = {}
    for s in range(num_steps):
        if G == 1:
            issued.setdefault((s, 0), []).append(s)
            continue
        if s == 0:
            for c in range(C):                       # step 0: all chunks now
                issued.setdefault((0, c), []).append(0)
            for d in range(1, C):                    # ramp: folded chunks
                if d < num_steps:
                    for c in range(0, C - d):
                        issued.setdefault((d, c), []).append(0)
        for d in range(1, G):                        # steady state
            c = C - d
            if c >= 0 and s + d < num_steps:
                issued.setdefault((s + d, c), []).append(s)
    return issued


def schedule_lane_events(trace, *, num_steps: int, G: int, C: int,
                         t0_us: float, dur_us: float, step_bytes: float = 0.0,
                         live=None, pid: int = 0, tid_dma: int = 0,
                         tid_compute: int = 1, max_events: int = 256,
                         name: str = "gpp") -> int:
    """Render the chunk-issue schedule as DMA-vs-compute trace lanes.

    Host-side observability companion to `_run_chunk_schedule`: replays
    `chunk_issue_schedule(num_steps, G, C)` — the exact issue pattern the
    kernel executes — and emits two lanes of Chrome trace-event "X" spans
    into `trace` (an `obs.trace.TraceRecorder`), scaled into the measured
    call window [t0_us, t0_us + dur_us]:

      tid_dma      chunk DMAs *started* per grid step (count + bytes — flat
                   at one tile per step once the ring is primed: the paper's
                   invariant, now visible on a timeline)
      tid_compute  the grid step's compute occupancy

    Timebase: the window is split evenly across LIVE grid steps (`live(s)`
    false ⇒ the step is skipped by the kernel's predicate and costs ~no
    time); a real per-step clock can't exist inside a Pallas body, so these
    lanes are a schedule-exact model stretched over the measured wall
    window — events carry cat="modeled" to say so.  Steps coalesce into at
    most `max_events` buckets per lane so long grids stay cheap to record.
    Returns the number of events emitted.
    """
    if not getattr(trace, "enabled", False) or num_steps <= 0 or dur_us <= 0:
        return 0
    sched = chunk_issue_schedule(num_steps, G, C)
    starts = [0] * num_steps            # chunk DMAs issued at each step
    for (step, chunk), at in sched.items():
        if live is None or live(step):
            for s in at:
                starts[s] += 1
    is_live = [bool(live(s)) if live is not None else True
               for s in range(num_steps)]
    n_live = sum(is_live)
    if n_live == 0:
        return 0
    dt = dur_us / n_live
    chunk_bytes = step_bytes / C if C else 0.0
    bucket = max(1, -(-num_steps // max_events))
    emitted = 0
    t = t0_us                           # start of the current bucket
    for b0 in range(0, num_steps, bucket):
        b1 = min(b0 + bucket, num_steps)
        live_in = sum(is_live[b0:b1])
        chunks = sum(starts[b0:b1])
        width = live_in * dt
        label = (f"{name} step {b0}" if bucket == 1
                 else f"{name} steps {b0}-{b1 - 1}")
        if chunks:
            trace.complete(
                f"{label} dma", t, width or dt * 0.1, pid=pid, tid=tid_dma,
                cat="modeled",
                args={"chunks_started": chunks,
                      "bytes": chunks * chunk_bytes, "ring": G})
            emitted += 1
        if live_in:
            trace.complete(
                f"{label} compute", t, width, pid=pid, tid=tid_compute,
                cat="modeled",
                args={"grid_steps": b1 - b0, "live_steps": live_in})
            emitted += 1
        t += width
    return emitted


def matmul_lane_events(trace, M: int, K: int, N: int, *,
                       itemsize: int = 4, t0_us: float, dur_us: float,
                       pid: int = 0, max_events: int = 256) -> int:
    """Schedule-exact DMA/compute lanes for one `gpp_matmul(M,K,N)` call:
    plans the same tiles/ring the kernel wrapper would and replays the
    chunk schedule into `trace` over the measured window."""
    plan = plan_matmul_tiles(M, K, N, x_itemsize=itemsize,
                             w_itemsize=itemsize, out_itemsize=itemsize)
    num_m, num_n, num_k = plan.grid(M, N, K)
    steps = num_m * num_n * num_k
    G = min(plan.num_bufs, max(1, steps))
    C = max(1, min(G - 1, plan.block_k))
    return schedule_lane_events(
        trace, num_steps=steps, G=G, C=C, t0_us=t0_us, dur_us=dur_us,
        step_bytes=plan.block_k * plan.block_n * itemsize,
        pid=pid, max_events=max_events, name="matmul")


def _make_chunk_ops(w_hbm, ring, sems, G: int, C: int, bk: int, tile_slice):
    """(start_chunk, wait_chunk) pair for the ring's chunk DMAs, shared by
    the flat and grouped kernels.  `tile_slice(step, lo, hi)` returns the
    w_hbm sub-ref holding rows [lo, hi) of the weight tile consumed at
    `step` — the step->tile mapping is the only per-grid difference."""
    def _copy(step, c: int):
        slot = jax.lax.rem(step, G)
        lo, hi = _chunk_bounds(bk, C, c)
        return pltpu.make_async_copy(
            tile_slice(step, lo, hi),
            ring.at[slot, pl.ds(lo, hi - lo), :],
            sems.at[slot],
        )

    return (lambda step, c: _copy(step, c).start(),
            lambda step, c: _copy(step, c).wait())


def _run_chunk_schedule(s, S: int, G: int, C: int, start_chunk, wait_chunk):
    """The GPP chunk-issue DMA schedule, shared by the flat and grouped
    kernel bodies (their step->tile mappings live in start/wait_chunk).

    G == 1 is in-situ (fetch-then-compute, nothing in flight).  Otherwise:
    step s's chunk c is issued at step s-C+c; steps < 0 fold into the step-0
    pipeline-fill prologue; at steady state, step s issues chunk C-d of step
    s+d for d = 1..G-1, then waits for all chunks of its own tile.  Mirrored
    by `chunk_issue_schedule` above — keep the two in lockstep.
    """
    if G == 1:
        start_chunk(s, 0)
        wait_chunk(s, 0)
        return

    @pl.when(s == 0)
    def _prologue():
        for c in range(C):                   # step 0 computes immediately
            start_chunk(0, c)
        for d in range(1, C):                # steps 1..C-1: folded chunks
            if d < S:                        # S is static
                for c in range(0, C - d):
                    start_chunk(d, c)

    for d in range(1, G):
        c = C - d
        if c < 0:
            continue

        @pl.when(s + d < S)
        def _(d=d, c=c):
            start_chunk(s + d, c)

    for c in range(C):
        wait_chunk(s, c)


def _gpp_kernel(*refs, grid_mnk: tuple, num_bufs: int, bm: int, bn: int,
                bk: int, C: int, has_scale: bool, has_bias: bool, activation,
                out_dtype, w_dtype, x_dtype):
    """Pallas kernel body; grid = (num_m, num_n, num_k), k innermost."""
    x_ref = refs[0]
    w_hbm = refs[1]
    i = 2
    scale_ref = bias_ref = None
    if has_scale:
        scale_ref = refs[i]; i += 1
    if has_bias:
        bias_ref = refs[i]; i += 1
    y_ref = refs[i]
    acc_ref, ring, sems = refs[i + 1], refs[i + 2], refs[i + 3]

    m, n, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    num_m, nn, nk = grid_mnk
    S = num_m * nn * nk                    # total sequential grid steps
    T = nn * nk                            # weight tiles per m-pass
    G = num_bufs
    s = (m * nn + n) * nk + k              # global step

    def tile_slice(step, lo: int, hi: int):
        """Rows [lo, hi) of the weight tile consumed at grid step `step`."""
        t = jax.lax.rem(step, T)
        n_idx, k_idx = t // nk, jax.lax.rem(t, nk)
        return w_hbm.at[pl.ds(k_idx * bk + lo, hi - lo), pl.ds(n_idx * bn, bn)]

    start_chunk, wait_chunk = _make_chunk_ops(w_hbm, ring, sems, G, C, bk,
                                              tile_slice)

    _run_chunk_schedule(s, S, G, C, start_chunk, wait_chunk)
    slot = jax.lax.rem(s, G)
    w_tile = ring[slot]
    x_tile = x_ref[...]
    if w_dtype != x_dtype or w_dtype == jnp.int8:
        # dtype-aware streaming: the tile was DMA'd raw (bf16/int8 bytes);
        # widen to f32 right before the MXU, accumulate in f32.
        w_tile = w_tile.astype(jnp.float32)
        x_tile = x_tile.astype(jnp.float32)
    contrib = jax.lax.dot_general(
        x_tile, w_tile,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = contrib

    @pl.when(k != 0)
    def _accum():
        acc_ref[...] = acc_ref[...] + contrib

    # fused epilogue on the last K step, before the output store.
    @pl.when(k == nk - 1)
    def _epilogue():
        out = acc_ref[...]
        if has_scale:
            out = out * scale_ref[...]           # (1, bn) dequant broadcast
        if has_bias:
            out = out + bias_ref[...]
        out = _ACTIVATIONS[activation](out)
        y_ref[...] = out.astype(out_dtype)


def _pad2(a: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    if a.shape == (rows, cols):
        return a
    return jnp.pad(a, ((0, rows - a.shape[0]), (0, cols - a.shape[1])))


def gpp_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    bias: jnp.ndarray | None = None,
    w_scale: jnp.ndarray | None = None,
    activation: str | None = None,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    num_bufs: int | None = None,
    vmem_budget: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Streaming matmul with the generalized ping-pong DMA schedule.

    Args:
      x: (M, K) activations (streamed through VMEM in (block_m, block_k)
         tiles; block_m is the paper's n_in).
      w: (K, N) weights in HBM, streamed in (block_k, block_n) tiles.  May be
         a narrower dtype than x (bf16/int8): tiles are DMA'd raw and widened
         in-kernel against f32 accumulation.
      bias: optional (N,) bias fused into the last-K-step epilogue.
      w_scale: optional per-column dequant scale — scalar or (N,) — applied
         to the f32 accumulator in the epilogue (int8 streaming).
      activation: optional fused activation: relu | gelu | silu | tanh.
      block_m/block_n/block_k: tile sizes; any left None is planned against
         the VMEM budget (`core.schedule.plan_matmul_tiles`).  Ragged edges
         are zero-padded, not errors.
      num_bufs: ring depth G — 1: in-situ, 2: naive ping-pong, >=3: GPP.
         None: planned from the DMA:compute ratio of one tile.
      vmem_budget: on-chip working-set budget in bytes (default ~100 MiB).
      interpret: run the kernel body in interpret mode (CPU validation).
    """
    M, K = x.shape
    K2, N = w.shape
    if K != K2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    if num_bufs is not None and num_bufs < 1:
        raise ValueError("num_bufs >= 1")
    if activation not in _ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    out_dtype = x.dtype

    plan_kw = dict(vmem_budget=vmem_budget) if vmem_budget is not None else {}
    plan = plan_matmul_tiles(
        M, K, N,
        x_itemsize=x.dtype.itemsize,
        w_itemsize=w.dtype.itemsize,
        out_itemsize=jnp.dtype(out_dtype).itemsize,
        block_m=block_m, block_n=block_n, block_k=block_k,
        num_bufs=num_bufs, **plan_kw,
    )
    bm, bn, bk = plan.block_m, plan.block_n, plan.block_k
    num_m, num_n, num_k = plan.grid(M, N, K)
    G = min(plan.num_bufs, max(1, num_m * num_n * num_k))
    # chunks per tile: C = G-1 splits of the block_k sublanes (clamped so
    # every chunk is non-empty even for tiny K tiles).
    C = max(1, min(G - 1, bk))

    # zero-pad ragged edges to full tiles (K-padding is correctness-neutral;
    # M/N padding is sliced off the output).
    Mp, Kp, Np = num_m * bm, num_k * bk, num_n * bn
    xp = _pad2(x, Mp, Kp)
    wp = _pad2(w, Kp, Np)

    operands = [xp, wp]
    in_specs = [
        pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),      # x tile
        pl.BlockSpec(memory_space=pl.ANY),                   # w: stays in HBM
    ]
    has_scale = w_scale is not None
    has_bias = bias is not None
    if has_scale:
        sc = jnp.broadcast_to(
            jnp.asarray(w_scale, jnp.float32).reshape(1, -1), (1, N))
        operands.append(_pad2(sc, 1, Np))
        in_specs.append(pl.BlockSpec((1, bn), lambda m, n, k: (0, n)))
    if has_bias:
        b = jnp.asarray(bias, jnp.float32).reshape(1, N)
        operands.append(_pad2(b, 1, Np))
        in_specs.append(pl.BlockSpec((1, bn), lambda m, n, k: (0, n)))

    kernel = functools.partial(
        _gpp_kernel, grid_mnk=(num_m, num_n, num_k), num_bufs=G,
        bm=bm, bn=bn, bk=bk, C=C,
        has_scale=has_scale, has_bias=has_bias, activation=activation,
        out_dtype=out_dtype, w_dtype=w.dtype, x_dtype=x.dtype,
    )
    y = pl.pallas_call(
        kernel,
        grid=(num_m, num_n, num_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),               # f32 accumulator
            pltpu.VMEM((G, bk, bn), w.dtype),                # weight ring
            pltpu.SemaphoreType.DMA((G,)),                   # per-slot DMA sems
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",) * 3,          # sequential grid
        ),
        interpret=interpret,
    )(*operands)
    if (Mp, Np) != (M, N):
        y = y[:M, :N]
    return y


# ---------------------------------------------------------------------------
# Grouped (batched-expert) variant: y[e] = epilogue(x[e] @ w[e])
# ---------------------------------------------------------------------------

def _gpp_grouped_kernel(*refs, grid_emnk: tuple, num_bufs: int, bm: int,
                        bn: int, bk: int, C: int, has_scale: bool,
                        has_bias: bool, activation,
                        out_dtype, w_dtype, x_dtype):
    """Pallas kernel body; grid = (E, num_m, num_n, num_k), k innermost.

    The expert axis is the *outermost ring dimension*: the global step
    sequence runs all of expert e's tiles, then expert e+1's, and the chunk
    schedule is phrased over global steps — so while the MXU finishes expert
    e's last tiles the ring is already streaming expert e+1's first weight
    tiles from HBM.  Each expert's weights cross the HBM link exactly once
    per (m-pass, n, k) visit, the PIM-DRAM batched-workload schedule
    (arXiv 2105.03736) mapped onto the TPU ring.
    """
    x_ref = refs[0]
    w_hbm = refs[1]
    i = 2
    scale_ref = bias_ref = None
    if has_scale:
        scale_ref = refs[i]; i += 1
    if has_bias:
        bias_ref = refs[i]; i += 1
    y_ref = refs[i]
    acc_ref, ring, sems = refs[i + 1], refs[i + 2], refs[i + 3]

    e, m, n, k = (pl.program_id(d) for d in range(4))
    E, num_m, nn, nk = grid_emnk
    SM = num_m * nn * nk                   # sequential steps per expert
    S = E * SM                             # total sequential grid steps
    T = nn * nk                            # weight tiles per m-pass
    G = num_bufs
    s = ((e * num_m + m) * nn + n) * nk + k   # global step

    def tile_coords(step):
        """Weight-tile coords (expert, k-tile, n-tile) consumed at `step`."""
        e_idx = step // SM
        t = jax.lax.rem(jax.lax.rem(step, SM), T)
        return e_idx, t // nk, jax.lax.rem(t, nk)

    def tile_slice(step, lo: int, hi: int):
        e_idx, n_idx, k_idx = tile_coords(step)
        return w_hbm.at[e_idx, pl.ds(k_idx * bk + lo, hi - lo),
                        pl.ds(n_idx * bn, bn)]

    start_chunk, wait_chunk = _make_chunk_ops(w_hbm, ring, sems, G, C, bk,
                                              tile_slice)

    # same chunk schedule as `_gpp_kernel`, over E*SM global steps: the
    # step->tile mapping is the only difference, so the flat one-tile-
    # per-step DMA invariant holds across expert boundaries too.
    _run_chunk_schedule(s, S, G, C, start_chunk, wait_chunk)
    slot = jax.lax.rem(s, G)
    w_tile = ring[slot]
    x_tile = x_ref[0]
    if w_dtype != x_dtype or w_dtype == jnp.int8:
        w_tile = w_tile.astype(jnp.float32)
        x_tile = x_tile.astype(jnp.float32)
    contrib = jax.lax.dot_general(
        x_tile, w_tile,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = contrib

    @pl.when(k != 0)
    def _accum():
        acc_ref[...] = acc_ref[...] + contrib

    @pl.when(k == nk - 1)
    def _epilogue():
        out = acc_ref[...]
        if has_scale:
            out = out * scale_ref[...]           # (1, bn) per-expert dequant
        if has_bias:
            out = out + bias_ref[...]
        out = _ACTIVATIONS[activation](out)
        y_ref[0] = out.astype(out_dtype)


def _pad3(a: jnp.ndarray, d1: int, d2: int) -> jnp.ndarray:
    if a.shape[1:] == (d1, d2):
        return a
    return jnp.pad(a, ((0, 0), (0, d1 - a.shape[1]), (0, d2 - a.shape[2])))


def gpp_matmul_grouped(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    bias: jnp.ndarray | None = None,
    w_scale: jnp.ndarray | None = None,
    activation: str | None = None,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    num_bufs: int | None = None,
    vmem_budget: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Batched-expert streaming matmul: y[e] = epilogue(x[e] @ w[e]).

    Args:
      x: (E, C, D) per-expert activation rows (MoE: C = expert capacity).
      w: (E, D, F) per-expert weights in HBM, streamed tile-by-tile with the
         expert axis as the outermost ring dimension (each expert's weights
         cross the link once per step; the ring pipelines across experts).
      bias: optional (E, F) per-expert bias fused into the epilogue.
      w_scale: optional per-expert per-column dequant scale — scalar, (E,)
         or (E, F) — applied to the f32 accumulator in the epilogue before
         bias/activation, so int8 expert weights stream raw through the ring
         and widen in-kernel exactly like the flat kernel's dequant path.
      activation: optional fused activation (see `_ACTIVATIONS`).
      block_*/num_bufs/vmem_budget: as `gpp_matmul`, planned per expert.
      interpret: run the kernel body in interpret mode (CPU validation).
    """
    E, M, K = x.shape
    E2, K2, N = w.shape
    if E != E2 or K != K2:
        raise ValueError(f"grouped shape mismatch: {x.shape} @ {w.shape}")
    if num_bufs is not None and num_bufs < 1:
        raise ValueError("num_bufs >= 1")
    if activation not in _ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    out_dtype = x.dtype

    plan_kw = dict(vmem_budget=vmem_budget) if vmem_budget is not None else {}
    plan = plan_matmul_tiles(
        M, K, N,
        x_itemsize=x.dtype.itemsize,
        w_itemsize=w.dtype.itemsize,
        out_itemsize=jnp.dtype(out_dtype).itemsize,
        block_m=block_m, block_n=block_n, block_k=block_k,
        num_bufs=num_bufs, **plan_kw,
    )
    bm, bn, bk = plan.block_m, plan.block_n, plan.block_k
    num_m, num_n, num_k = plan.grid(M, N, K)
    G = min(plan.num_bufs, max(1, E * num_m * num_n * num_k))
    C = max(1, min(G - 1, bk))

    Mp, Kp, Np = num_m * bm, num_k * bk, num_n * bn
    xp = _pad3(x, Mp, Kp)
    wp = _pad3(w, Kp, Np)

    operands = [xp, wp]
    in_specs = [
        pl.BlockSpec((1, bm, bk), lambda e, m, n, k: (e, m, k)),  # x tile
        pl.BlockSpec(memory_space=pl.ANY),                        # w: HBM
    ]
    has_scale = w_scale is not None
    has_bias = bias is not None
    if has_scale:
        sc = jnp.asarray(w_scale, jnp.float32)
        sc = jnp.broadcast_to(sc if sc.ndim == 0 else sc.reshape(E, -1), (E, N))
        if N != Np:
            sc = jnp.pad(sc, ((0, 0), (0, Np - N)))
        operands.append(sc)
        in_specs.append(pl.BlockSpec((1, bn), lambda e, m, n, k: (e, n)))
    if has_bias:
        b = jnp.asarray(bias, jnp.float32).reshape(E, N)
        if N != Np:
            b = jnp.pad(b, ((0, 0), (0, Np - N)))
        operands.append(b)
        in_specs.append(pl.BlockSpec((1, bn), lambda e, m, n, k: (e, n)))

    kernel = functools.partial(
        _gpp_grouped_kernel, grid_emnk=(E, num_m, num_n, num_k), num_bufs=G,
        bm=bm, bn=bn, bk=bk, C=C, has_scale=has_scale, has_bias=has_bias,
        activation=activation,
        out_dtype=out_dtype, w_dtype=w.dtype, x_dtype=x.dtype,
    )
    y = pl.pallas_call(
        kernel,
        grid=(E, num_m, num_n, num_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, m, n, k: (e, m, n)),
        out_shape=jax.ShapeDtypeStruct((E, Mp, Np), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),               # f32 accumulator
            pltpu.VMEM((G, bk, bn), w.dtype),                # weight ring
            pltpu.SemaphoreType.DMA((G,)),                   # per-slot sems
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",) * 4,          # sequential grid
        ),
        interpret=interpret,
    )(*operands)
    if (Mp, Np) != (M, N):
        y = y[:, :M, :N]
    return y
