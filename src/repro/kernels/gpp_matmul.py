"""Generalized ping-pong streaming matmul — the paper's GeMM engine on TPU.

y[M, N] = epilogue(x[M, K] @ W[K, N]) where W is too large to be VMEM-resident
and streams from HBM ("off-chip") while the MXU computes — the PIM
concurrent-write/compute problem mapped to the TPU memory hierarchy
(DESIGN.md §2.1), now on a 3-D (num_m, num_n, num_k) grid so arbitrary M/K/N
fit in VMEM:

  PIM macro           ->  one (block_k, block_n) weight tile resident in VMEM
  weight rewrite      ->  async HBM->VMEM DMA into a ring slot
  n_in input vectors  ->  the block_m rows matmul'd against the resident tile
  off-chip bandwidth  ->  HBM DMA bandwidth
  consecutive GeMMs   ->  the flattened sequence of grid steps: k innermost
                          (f32 accumulator carried across), then n, then m

Strategies (selected by `num_bufs`):
  num_bufs == 1   in-situ: DMA tile for step s, wait, compute (bursty, stalls)
  num_bufs == 2   naive ping-pong: classic double buffering — whole-tile DMA
                  for step s+1 issued while computing step s
  num_bufs >= 3   generalized ping-pong: ring of G buffers; while computing
                  step s, issue ONE CHUNK (1/C of a tile, C = G-1) for each of
                  the C upcoming steps, so DMA traffic is flat at exactly one
                  tile per compute step and the MXU never waits even when
                  t_dma > t_compute.

The chunk schedule is the seed 1-D schedule re-derived over *global grid
steps* instead of N-tiles: with S = num_m*num_n*num_k sequential steps, the
weight tile needed at step s is tile(s) = s mod (num_n*num_k) (column tile
n = tile//num_k, K-tile k = tile mod num_k), its ring slot is s mod G, and
chunk c of step s's tile is issued at step s-C+c (steps < 0 fold into the
step-0 pipeline-fill prologue).  Because the schedule is phrased in steps,
the one-tile-per-step flat-bandwidth invariant holds across k-loop, n-loop
and m-loop boundaries alike — including the ragged final tiles, which the
wrapper zero-pads to full blocks.  Coverage proof:
tests/test_kernels.py::TestSchedule.

Grid steps on TPU run sequentially on one core ("arbitrary" dimension
semantics), so DMA state (semaphore signals) persists across steps — the
standard Pallas manual-multibuffering pattern.  Chunks split the block_k
(sublane) dimension so each DMA keeps full 128-lane rows.

Epilogue (fused into the last K step, before the output store, all in f32):
  optional per-column dequant scale (int8/bf16 weights are DMA'd raw and
  widened in-kernel), optional bias add, optional activation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.schedule import plan_matmul_tiles

# renamed CompilerParams -> TPUCompilerParams across jax versions
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

_ACTIVATIONS = {
    None: lambda x: x,
    "none": lambda x: x,
    "relu": lambda x: jnp.maximum(x, 0.0),
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "tanh": jnp.tanh,
}


def _chunk_bounds(K: int, chunks: int, c: int) -> tuple[int, int]:
    base = K // chunks
    lo = c * base
    hi = K if c == chunks - 1 else lo + base
    return lo, hi


def chunk_issue_schedule(num_steps: int, G: int,
                         C: int) -> "dict[tuple[int, int], list[int]]":
    """Pure-Python replay of the kernel's DMA issue schedule.

    Returns {(step, chunk): [issue_steps]} — the steps at which chunk `chunk`
    of the weight tile consumed at `step` is DMA'd.  Mirrors `_gpp_kernel`'s
    loops one-for-one; the schedule tests assert every (step, chunk) appears
    exactly once, at or before its consuming step.
    """
    issued: dict[tuple[int, int], list[int]] = {}
    for s in range(num_steps):
        if G == 1:
            issued.setdefault((s, 0), []).append(s)
            continue
        if s == 0:
            for c in range(C):                       # step 0: all chunks now
                issued.setdefault((0, c), []).append(0)
            for d in range(1, C):                    # ramp: folded chunks
                if d < num_steps:
                    for c in range(0, C - d):
                        issued.setdefault((d, c), []).append(0)
        for d in range(1, G):                        # steady state
            c = C - d
            if c >= 0 and s + d < num_steps:
                issued.setdefault((s + d, c), []).append(s)
    return issued


def _gpp_kernel(*refs, grid_mnk: tuple, num_bufs: int, bm: int, bn: int,
                bk: int, C: int, has_scale: bool, has_bias: bool, activation,
                out_dtype, w_dtype, x_dtype):
    """Pallas kernel body; grid = (num_m, num_n, num_k), k innermost."""
    x_ref = refs[0]
    w_hbm = refs[1]
    i = 2
    scale_ref = bias_ref = None
    if has_scale:
        scale_ref = refs[i]; i += 1
    if has_bias:
        bias_ref = refs[i]; i += 1
    y_ref = refs[i]
    acc_ref, ring, sems = refs[i + 1], refs[i + 2], refs[i + 3]

    m, n, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    num_m, nn, nk = grid_mnk
    S = num_m * nn * nk                    # total sequential grid steps
    T = nn * nk                            # weight tiles per m-pass
    G = num_bufs
    s = (m * nn + n) * nk + k              # global step

    def start_chunk(step, c: int):
        """Issue async DMA of chunk c of the weight tile for grid step `step`."""
        t = jax.lax.rem(step, T)
        n_idx, k_idx = t // nk, jax.lax.rem(t, nk)
        slot = jax.lax.rem(step, G)
        lo, hi = _chunk_bounds(bk, C, c)
        pltpu.make_async_copy(
            w_hbm.at[pl.ds(k_idx * bk + lo, hi - lo), pl.ds(n_idx * bn, bn)],
            ring.at[slot, pl.ds(lo, hi - lo), :],
            sems.at[slot],
        ).start()

    def wait_chunk(step, c: int):
        t = jax.lax.rem(step, T)
        n_idx, k_idx = t // nk, jax.lax.rem(t, nk)
        slot = jax.lax.rem(step, G)
        lo, hi = _chunk_bounds(bk, C, c)
        pltpu.make_async_copy(
            w_hbm.at[pl.ds(k_idx * bk + lo, hi - lo), pl.ds(n_idx * bn, bn)],
            ring.at[slot, pl.ds(lo, hi - lo), :],
            sems.at[slot],
        ).wait()

    if G == 1:
        # in-situ: fetch-then-compute every step, nothing in flight.
        start_chunk(s, 0)
        wait_chunk(s, 0)
    else:
        # Chunk schedule: step s's chunk c is issued at step s-C+c; steps < 0
        # fold into the step-0 pipeline-fill prologue.  Mirrored by
        # `chunk_issue_schedule` above — keep the two in lockstep.
        @pl.when(s == 0)
        def _prologue():
            for c in range(C):                   # step 0 computes immediately
                start_chunk(0, c)
            for d in range(1, C):                # steps 1..C-1: folded chunks
                if d < S:                        # S is static
                    for c in range(0, C - d):
                        start_chunk(d, c)

        # steady state: at step s issue chunk C-d of step s+d, d = 1..G-1.
        for d in range(1, G):
            c = C - d
            if c < 0:
                continue

            @pl.when(s + d < S)
            def _(d=d, c=c):
                start_chunk(s + d, c)

    # wait for all chunks of step s's tile, then compute this K-slice.
    if G >= 2:
        for c in range(C):
            wait_chunk(s, c)
    slot = jax.lax.rem(s, G)
    w_tile = ring[slot]
    x_tile = x_ref[...]
    if w_dtype != x_dtype or w_dtype == jnp.int8:
        # dtype-aware streaming: the tile was DMA'd raw (bf16/int8 bytes);
        # widen to f32 right before the MXU, accumulate in f32.
        w_tile = w_tile.astype(jnp.float32)
        x_tile = x_tile.astype(jnp.float32)
    contrib = jax.lax.dot_general(
        x_tile, w_tile,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = contrib

    @pl.when(k != 0)
    def _accum():
        acc_ref[...] = acc_ref[...] + contrib

    # fused epilogue on the last K step, before the output store.
    @pl.when(k == nk - 1)
    def _epilogue():
        out = acc_ref[...]
        if has_scale:
            out = out * scale_ref[...]           # (1, bn) dequant broadcast
        if has_bias:
            out = out + bias_ref[...]
        out = _ACTIVATIONS[activation](out)
        y_ref[...] = out.astype(out_dtype)


def _pad2(a: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    if a.shape == (rows, cols):
        return a
    return jnp.pad(a, ((0, rows - a.shape[0]), (0, cols - a.shape[1])))


def gpp_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    bias: jnp.ndarray | None = None,
    w_scale: jnp.ndarray | None = None,
    activation: str | None = None,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    num_bufs: int | None = None,
    vmem_budget: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Streaming matmul with the generalized ping-pong DMA schedule.

    Args:
      x: (M, K) activations (streamed through VMEM in (block_m, block_k)
         tiles; block_m is the paper's n_in).
      w: (K, N) weights in HBM, streamed in (block_k, block_n) tiles.  May be
         a narrower dtype than x (bf16/int8): tiles are DMA'd raw and widened
         in-kernel against f32 accumulation.
      bias: optional (N,) bias fused into the last-K-step epilogue.
      w_scale: optional per-column dequant scale — scalar or (N,) — applied
         to the f32 accumulator in the epilogue (int8 streaming).
      activation: optional fused activation: relu | gelu | silu | tanh.
      block_m/block_n/block_k: tile sizes; any left None is planned against
         the VMEM budget (`core.schedule.plan_matmul_tiles`).  Ragged edges
         are zero-padded, not errors.
      num_bufs: ring depth G — 1: in-situ, 2: naive ping-pong, >=3: GPP.
         None: planned from the DMA:compute ratio of one tile.
      vmem_budget: on-chip working-set budget in bytes (default ~100 MiB).
      interpret: run the kernel body in interpret mode (CPU validation).
    """
    M, K = x.shape
    K2, N = w.shape
    if K != K2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    if num_bufs is not None and num_bufs < 1:
        raise ValueError("num_bufs >= 1")
    if activation not in _ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    out_dtype = x.dtype

    plan_kw = dict(vmem_budget=vmem_budget) if vmem_budget is not None else {}
    plan = plan_matmul_tiles(
        M, K, N,
        x_itemsize=x.dtype.itemsize,
        w_itemsize=w.dtype.itemsize,
        out_itemsize=jnp.dtype(out_dtype).itemsize,
        block_m=block_m, block_n=block_n, block_k=block_k,
        num_bufs=num_bufs, **plan_kw,
    )
    bm, bn, bk = plan.block_m, plan.block_n, plan.block_k
    num_m, num_n, num_k = plan.grid(M, N, K)
    G = min(plan.num_bufs, max(1, num_m * num_n * num_k))
    # chunks per tile: C = G-1 splits of the block_k sublanes (clamped so
    # every chunk is non-empty even for tiny K tiles).
    C = max(1, min(G - 1, bk))

    # zero-pad ragged edges to full tiles (K-padding is correctness-neutral;
    # M/N padding is sliced off the output).
    Mp, Kp, Np = num_m * bm, num_k * bk, num_n * bn
    xp = _pad2(x, Mp, Kp)
    wp = _pad2(w, Kp, Np)

    operands = [xp, wp]
    in_specs = [
        pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),      # x tile
        pl.BlockSpec(memory_space=pl.ANY),                   # w: stays in HBM
    ]
    has_scale = w_scale is not None
    has_bias = bias is not None
    if has_scale:
        sc = jnp.broadcast_to(
            jnp.asarray(w_scale, jnp.float32).reshape(1, -1), (1, N))
        operands.append(_pad2(sc, 1, Np))
        in_specs.append(pl.BlockSpec((1, bn), lambda m, n, k: (0, n)))
    if has_bias:
        b = jnp.asarray(bias, jnp.float32).reshape(1, N)
        operands.append(_pad2(b, 1, Np))
        in_specs.append(pl.BlockSpec((1, bn), lambda m, n, k: (0, n)))

    kernel = functools.partial(
        _gpp_kernel, grid_mnk=(num_m, num_n, num_k), num_bufs=G,
        bm=bm, bn=bn, bk=bk, C=C,
        has_scale=has_scale, has_bias=has_bias, activation=activation,
        out_dtype=out_dtype, w_dtype=w.dtype, x_dtype=x.dtype,
    )
    y = pl.pallas_call(
        kernel,
        grid=(num_m, num_n, num_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),               # f32 accumulator
            pltpu.VMEM((G, bk, bn), w.dtype),                # weight ring
            pltpu.SemaphoreType.DMA((G,)),                   # per-slot DMA sems
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",) * 3,          # sequential grid
        ),
        interpret=interpret,
    )(*operands)
    if (Mp, Np) != (M, N):
        y = y[:M, :N]
    return y
