"""Generalized ping-pong streaming matmul — the paper's GeMM engine on TPU.

y[M, N] = x[M, K] @ W[K, N] where W is too large to be VMEM-resident and
streams from HBM ("off-chip") while the MXU computes — the PIM
concurrent-write/compute problem mapped to the TPU memory hierarchy
(DESIGN.md §2.1):

  PIM macro           ->  one (K, bn) weight tile resident in VMEM
  weight rewrite      ->  async HBM->VMEM DMA into a ring slot
  n_in input vectors  ->  the M rows matmul'd against the resident tile
  off-chip bandwidth  ->  HBM DMA bandwidth

Strategies (selected by `num_bufs`):
  num_bufs == 1   in-situ: DMA tile j, wait, compute tile j (bursty, stalls)
  num_bufs == 2   naive ping-pong: classic double buffering — whole-tile DMA
                  for j+1 issued while computing j
  num_bufs >= 3   generalized ping-pong: ring of G buffers; while computing
                  tile j, issue ONE CHUNK (1/(G-1) of a tile) for each of the
                  G-1 upcoming tiles, so DMA traffic is flat at exactly one
                  tile per compute step and the MXU never waits even when
                  t_dma > t_compute.

The chunk schedule is the same one validated against the paper's analytic
model: tile t's chunk c is issued at grid step t-(G-1)+c (clamped to 0 —
pipeline-fill ramp), i.e. at step j we issue chunk (G-1-k) of tile j+k.

Grid steps on TPU run sequentially on one core, so DMA state (semaphore
signals) persists across steps — the standard Pallas manual-multibuffering
pattern.  Chunks split the K (sublane) dimension so each DMA keeps full
128-lane rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _chunk_bounds(K: int, chunks: int, c: int) -> tuple[int, int]:
    base = K // chunks
    lo = c * base
    hi = K if c == chunks - 1 else lo + base
    return lo, hi


def _gpp_kernel(x_ref, w_hbm, y_ref, ring, sems, *, num_bufs: int, bn: int, K: int,
                out_dtype):
    """Pallas kernel body; grid = (num_tiles,) over N column-tiles of W."""
    j = pl.program_id(0)
    nt = pl.num_programs(0)
    G = num_bufs
    C = max(1, G - 1)  # chunks per tile

    def start_chunk(tile, c: int):
        """Issue async DMA of chunk c of weight tile `tile` into its slot."""
        lo, hi = _chunk_bounds(K, C, c)
        slot = jax.lax.rem(tile, G)
        copy = pltpu.make_async_copy(
            w_hbm.at[pl.ds(lo, hi - lo), pl.ds(tile * bn, bn)],
            ring.at[slot, pl.ds(lo, hi - lo), :],
            sems.at[slot],
        )
        copy.start()

    def wait_chunk(tile, c: int):
        lo, hi = _chunk_bounds(K, C, c)
        slot = jax.lax.rem(tile, G)
        pltpu.make_async_copy(
            w_hbm.at[pl.ds(lo, hi - lo), pl.ds(tile * bn, bn)],
            ring.at[slot, pl.ds(lo, hi - lo), :],
            sems.at[slot],
        ).wait()

    if G == 1:
        # in-situ: fetch-then-compute every step, nothing in flight.
        start_chunk(j, 0)
        wait_chunk(j, 0)
    else:
        # Chunk schedule: tile t's chunk c is issued at step t-C+c; steps < 0
        # fold into the step-0 pipeline-fill prologue.  Coverage proof in
        # tests/test_kernels.py::test_chunk_schedule_covers_every_chunk_once.
        @pl.when(j == 0)
        def _prologue():
            # tile 0 computes immediately: all C chunks now.
            for c in range(C):
                start_chunk(0, c)
            # tiles 1..G-2: chunks 0..C-1-k had negative scheduled steps.
            for k in range(1, G - 1):
                if k >= 1:  # tile index is static here
                    for c in range(0, C - k):
                        @pl.when(k < nt)
                        def _(k=k, c=c):
                            start_chunk(k, c)

        # steady state: at step j issue chunk C-k of tile j+k, k = 1..G-1.
        for k in range(1, G):
            c = C - k
            if c < 0:
                continue

            @pl.when(j + k < nt)
            def _(k=k, c=c):
                start_chunk(j + k, c)

    # wait for all chunks of tile j, then compute.
    if G >= 2:
        for c in range(C):
            wait_chunk(j, c)
    slot = jax.lax.rem(j, G)
    w_tile = ring[slot]
    acc = jax.lax.dot_general(
        x_ref[...], w_tile,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    y_ref[...] = acc.astype(out_dtype)


def gpp_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    block_n: int = 256,
    num_bufs: int = 4,
    interpret: bool = False,
) -> jnp.ndarray:
    """Streaming matmul with the generalized ping-pong DMA schedule.

    Args:
      x: (M, K) activations (VMEM-resident; M is the paper's n_in).
      w: (K, N) weights in HBM, streamed in (K, block_n) column tiles.
      block_n: weight tile width; multiple of 128 (MXU lane alignment).
      num_bufs: ring depth G — 1: in-situ, 2: naive ping-pong, >=3: GPP.
      interpret: run the kernel body in interpret mode (CPU validation).
    """
    M, K = x.shape
    K2, N = w.shape
    if K != K2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    if N % block_n != 0:
        raise ValueError(f"N={N} must be divisible by block_n={block_n}")
    if num_bufs < 1:
        raise ValueError("num_bufs >= 1")
    num_tiles = N // block_n
    G = min(num_bufs, max(1, num_tiles))
    C = max(1, G - 1)
    if K < C:
        raise ValueError(f"K={K} too small to split into {C} chunks")

    # VMEM budget sanity (target TPU v5e ~128 MiB/core): ring + x + y block.
    vmem_bytes = (G * K * block_n + M * K + M * block_n) * x.dtype.itemsize
    if vmem_bytes > 100 * 1024 * 1024:
        raise ValueError(
            f"working set {vmem_bytes/2**20:.1f} MiB exceeds VMEM budget; "
            f"reduce block_n or num_bufs"
        )

    kernel = functools.partial(
        _gpp_kernel, num_bufs=G, bn=block_n, K=K, out_dtype=x.dtype
    )
    return pl.pallas_call(
        kernel,
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec((M, K), lambda j: (0, 0)),          # x: VMEM resident
            pl.BlockSpec(memory_space=pl.ANY),               # w: stays in HBM
        ],
        out_specs=pl.BlockSpec((M, block_n), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, K, block_n), x.dtype),            # weight ring
            pltpu.SemaphoreType.DMA((G,)),                   # per-slot DMA sems
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",),              # sequential grid
        ),
        interpret=interpret,
    )(x, w)
