"""Pure-jnp oracles for the Pallas kernels."""
import jax.numpy as jnp


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """y[M,N] = x[M,K] @ w[K,N] accumulated in f32, cast back to x.dtype."""
    acc = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    return acc.astype(x.dtype)


def dense_ref(x: jnp.ndarray, w: jnp.ndarray, *, bias=None, w_scale=None,
              activation: str | None = None) -> jnp.ndarray:
    """Oracle for gpp_matmul's fused epilogue: f32 accumulation, then
    per-column dequant scale, bias, activation — all in f32 — cast to x.dtype."""
    from repro.kernels.gpp_matmul import _ACTIVATIONS  # single source of truth
    acc = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    if w_scale is not None:
        acc = acc * jnp.asarray(w_scale, jnp.float32).reshape(1, -1)
    if bias is not None:
        acc = acc + jnp.asarray(bias, jnp.float32).reshape(1, -1)
    return _ACTIVATIONS[activation](acc).astype(x.dtype)


def dense_grouped_ref(x: jnp.ndarray, w: jnp.ndarray, *, bias=None,
                      w_scale=None, activation: str | None = None) -> jnp.ndarray:
    """Oracle for gpp_matmul_grouped's fused epilogue: per-expert
    y[e] = act(x[e] @ w[e] [* w_scale[e]] [+ bias[e]]), f32 accumulation
    with the dequant scale applied post-accumulation (int8 streaming),
    cast to x.dtype."""
    from repro.kernels.gpp_matmul import _ACTIVATIONS  # single source of truth
    E = x.shape[0]
    acc = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                     w.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    if w_scale is not None:
        sc = jnp.asarray(w_scale, jnp.float32)
        sc = sc if sc.ndim == 0 else sc.reshape(E, 1, -1)
        acc = acc * sc
    if bias is not None:
        acc = acc + jnp.asarray(bias, jnp.float32)[:, None, :]
    return _ACTIVATIONS[activation](acc).astype(x.dtype)


def paged_attn_ref(q, pool_a, pool_b, tables, positions, *, num_kv_heads,
                   scale, window=None, mla=False) -> jnp.ndarray:
    """Oracle for `kernels.paged_attention.paged_attention`: gather the pools
    through the block tables into each lane's (MB*bs, ...) logical sequence,
    then run the exact `models.attention._sdpa` math (same casts, same f32
    accumulation, same -1e30 masking) over a dense position mask.  This IS
    the pre-kernel serving read path — mode="ref" routes here so existing
    paged-engine numerics are unchanged when the kernel is off.
    """
    import jax

    B, S, H, dk = q.shape
    bs = pool_a.shape[1]

    def gather(pool):
        g = pool[tables]                          # (B, MB, bs, ...)
        return g.reshape(B, -1, *pool.shape[2:])

    if mla:
        kseq = jnp.concatenate([gather(pool_a), gather(pool_b)], axis=-1)
        kseq = kseq[:, :, None, :]                # MQA: one shared kv head
        vseq = gather(pool_a)[:, :, None, :]
        kvh = 1
    else:
        kvh = num_kv_heads
        kseq = gather(pool_a)                     # (B, T, KVH, hd)
        vseq = gather(pool_b)
    T = kseq.shape[1]

    # dense mask over the gathered sequence: key slot t holds absolute
    # position t; query row s sits at positions[b] + s.
    qpos = positions[:, None, None] + jnp.arange(S)[None, :, None]
    kpos = jnp.arange(T)[None, None, :]
    mask = kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window

    # _sdpa replica (models/attention.py) — keep the two in lockstep.
    rep = H // kvh
    qr = (q.astype(jnp.float32) * scale).astype(kseq.dtype)
    qr = qr.reshape(B, S, kvh, rep, dk)
    logits = jnp.einsum("bsgrh,btgh->bgrst", qr, kseq,
                        preferred_element_type=jnp.float32)
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrst,btgh->bsgrh", probs.astype(vseq.dtype), vseq,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, S, H, vseq.shape[-1]).astype(q.dtype)


def streamed_gemm_seq_ref(x: jnp.ndarray, ws: jnp.ndarray) -> jnp.ndarray:
    """Reference for a *sequence* of GeMMs with streamed weights (the paper's
    consecutive-GeMM BLAS workload): ys[r] = x @ ws[r] for each round r."""
    return jnp.einsum(
        "mk,rkn->rmn",
        x.astype(jnp.float32),
        ws.astype(jnp.float32),
    ).astype(x.dtype)
