"""Pure-jnp oracles for the Pallas kernels."""
import jax.numpy as jnp


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """y[M,N] = x[M,K] @ w[K,N] accumulated in f32, cast back to x.dtype."""
    acc = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    return acc.astype(x.dtype)


def streamed_gemm_seq_ref(x: jnp.ndarray, ws: jnp.ndarray) -> jnp.ndarray:
    """Reference for a *sequence* of GeMMs with streamed weights (the paper's
    consecutive-GeMM BLAS workload): ys[r] = x @ ws[r] for each round r."""
    return jnp.einsum(
        "mk,rkn->rmn",
        x.astype(jnp.float32),
        ws.astype(jnp.float32),
    ).astype(x.dtype)
