"""Pure-jnp oracles for the Pallas kernels."""
import jax.numpy as jnp


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """y[M,N] = x[M,K] @ w[K,N] accumulated in f32, cast back to x.dtype."""
    acc = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    return acc.astype(x.dtype)


def dense_ref(x: jnp.ndarray, w: jnp.ndarray, *, bias=None, w_scale=None,
              activation: str | None = None) -> jnp.ndarray:
    """Oracle for gpp_matmul's fused epilogue: f32 accumulation, then
    per-column dequant scale, bias, activation — all in f32 — cast to x.dtype."""
    from repro.kernels.gpp_matmul import _ACTIVATIONS  # single source of truth
    acc = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    if w_scale is not None:
        acc = acc * jnp.asarray(w_scale, jnp.float32).reshape(1, -1)
    if bias is not None:
        acc = acc + jnp.asarray(bias, jnp.float32).reshape(1, -1)
    return _ACTIVATIONS[activation](acc).astype(x.dtype)


def dense_grouped_ref(x: jnp.ndarray, w: jnp.ndarray, *, bias=None,
                      w_scale=None, activation: str | None = None) -> jnp.ndarray:
    """Oracle for gpp_matmul_grouped's fused epilogue: per-expert
    y[e] = act(x[e] @ w[e] [* w_scale[e]] [+ bias[e]]), f32 accumulation
    with the dequant scale applied post-accumulation (int8 streaming),
    cast to x.dtype."""
    from repro.kernels.gpp_matmul import _ACTIVATIONS  # single source of truth
    E = x.shape[0]
    acc = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                     w.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    if w_scale is not None:
        sc = jnp.asarray(w_scale, jnp.float32)
        sc = sc if sc.ndim == 0 else sc.reshape(E, 1, -1)
        acc = acc * sc
    if bias is not None:
        acc = acc + jnp.asarray(bias, jnp.float32)[:, None, :]
    return _ACTIVATIONS[activation](acc).astype(x.dtype)


def streamed_gemm_seq_ref(x: jnp.ndarray, ws: jnp.ndarray) -> jnp.ndarray:
    """Reference for a *sequence* of GeMMs with streamed weights (the paper's
    consecutive-GeMM BLAS workload): ys[r] = x @ ws[r] for each round r."""
    return jnp.einsum(
        "mk,rkn->rmn",
        x.astype(jnp.float32),
        ws.astype(jnp.float32),
    ).astype(x.dtype)
