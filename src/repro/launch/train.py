"""End-to-end training driver.

CPU/examples:  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \\
                   --smoke --steps 20 --batch 8 --seq 128 --devices 4
Fleet:         the same entry point under jax.distributed (one process per
               host); the mesh comes from make_production_mesh() and the data
               pipeline shards by host id.

Fault tolerance in the loop: deterministic data (seed, step), async atomic
checkpoints every --ckpt-every steps, automatic resume from the latest
committed step, straggler watchdog on step wall-times.
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8, help="global batch")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (CPU dev-mode); 0 = as-is")
    ap.add_argument("--mesh", default="auto",
                    help="'auto' | 'DxM' e.g. 4x2 | 'production'")
    ap.add_argument("--stream-mode", default=None,
                    choices=["resident", "insitu", "naive_pp", "gpp"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.checkpoint.manager import CheckpointManager
    from repro.configs.base import ShapeConfig
    from repro.core.streamer import StreamSettings
    from repro.data.pipeline import DataConfig, TokenPipeline
    try:
        from repro.dist.fault import StepWatchdog
    except ImportError:
        # repro.dist was never built (planned fault-tolerance package).
        # Inline fallback with the same contract: record(dt) -> True when a
        # step straggles past 3x the median of recent steps (bounded window
        # so the hot loop stays O(window) regardless of run length).
        import collections
        import statistics

        class StepWatchdog:
            def __init__(self, factor: float = 3.0, window: int = 256):
                self.factor = factor
                self._times = collections.deque(maxlen=window)

            @property
            def median(self) -> float:
                return statistics.median(self._times) if self._times else 0.0

            def record(self, dt: float) -> bool:
                straggled = (len(self._times) >= 3
                             and dt > self.factor * self.median)
                self._times.append(dt)
                return straggled
    from repro.launch.mesh import (make_host_mesh, make_production_mesh,
                                       mesh_context)
    from repro.launch.steps import make_train_step
    from repro.models import registry
    from repro.models import transformer as tf
    from repro.optim import adafactor as adaf
    from repro.optim import adamw as adam

    cfg = registry.get_config(args.arch, smoke=args.smoke)
    if args.stream_mode:
        cfg = cfg.with_(stream=StreamSettings(mode=args.stream_mode,
                                              ring_depth=cfg.stream.ring_depth))

    if args.mesh == "production":
        mesh = make_production_mesh()
    elif args.mesh == "auto":
        n = len(jax.devices())
        d = max(1, n // 2)
        mesh = make_host_mesh(d, n // d)
    else:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_host_mesh(d, m)
    print(f"mesh: {dict(mesh.shape)}  devices: {len(jax.devices())}")

    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    with mesh_context(mesh):
        bundle = make_train_step(cfg, mesh, shape)

        key = jax.random.PRNGKey(0)
        params = tf.init_params(cfg, key)
        params = jax.device_put(params, bundle.arg_shardings[0])
        if cfg.optimizer == "adafactor":
            opt_state = adaf.adafactor_init(params)
        else:
            opt_state = adam.adamw_init(params)
        opt_state = jax.device_put(opt_state, bundle.arg_shardings[1])

        start_step = 0
        mgr = None
        if args.ckpt_dir:
            mgr = CheckpointManager(args.ckpt_dir)
            if mgr.latest_step() is not None:
                state, start_step = mgr.restore(
                    {"params": params, "opt": opt_state},
                    shardings={"params": bundle.arg_shardings[0],
                               "opt": bundle.arg_shardings[1]})
                params, opt_state = state["params"], state["opt"]
                print(f"resumed from step {start_step}")

        pipe = TokenPipeline(cfg, DataConfig(
            seed=1234, batch=args.batch, seq_len=args.seq)).start(start_step)
        watchdog = StepWatchdog()
        losses = []
        try:
            for step in range(start_step, args.steps):
                batch_np = next(pipe)
                batch = {k: jax.device_put(v, bundle.arg_shardings[2][k])
                         for k, v in batch_np.items()}
                t0 = time.time()
                params, opt_state, metrics = bundle.fn(
                    params, opt_state, batch, jax.numpy.asarray(step))
                loss = float(metrics["loss"])
                dt = time.time() - t0
                losses.append(loss)
                if watchdog.record(dt):
                    print(f"[watchdog] step {step} straggled: {dt:.2f}s "
                          f"(median {watchdog.median:.2f}s)")
                if step % args.log_every == 0:
                    print(f"step {step:5d} loss {loss:8.4f} "
                          f"gnorm {float(metrics['grad_norm']):8.3f} {dt*1e3:7.1f} ms")
                if mgr and step and step % args.ckpt_every == 0:
                    mgr.save(step, {"params": params, "opt": opt_state},
                             blocking=False)
        finally:
            pipe.stop()
            if mgr:
                mgr.wait()

        if mgr:
            mgr.save(args.steps, {"params": params, "opt": opt_state})
        print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
        return losses


if __name__ == "__main__":
    main()
