"""Roofline analysis: three terms per (arch x shape x mesh) from the compiled
dry-run artifact.

    compute    = HLO_FLOPs / (chips * 197e12)        [bf16 TPU v5e]
    memory     = HLO_bytes / (chips * 819e9)         [HBM]
    collective = coll_bytes / (chips * 50e9 * links) [ICI]

Sources: `cost_analysis()` for FLOPs/bytes (per-partition on the SPMD
module); collective bytes from the optimized HLO with WHILE-LOOP TRIP COUNT
awareness — a collective inside the scan-over-layers body executes
`num_superblocks` times but appears once in the text, so the naive sum
undercounts ~60x on deep models.  Each term is also cross-checked against an
analytic model (MODEL_FLOPS = 6*N*D etc.) and both are reported.

Loop handling: HLO computations are parsed into blocks; `while` ops carry
known trip counts on the CPU backend either in backend_config
(known_trip_count) or implicitly — when absent we fall back to the model's
layer count for the outermost loop and 1 elsewhere (conservative).
"""
from __future__ import annotations

import dataclasses
import json
import re

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link
ICI_LINKS = 3                # v5e: 3 usable link-pairs per chip in a 2D torus

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}
_SHAPE_RE = re.compile(
    r"(?P<dt>bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|pred)\[(?P<dims>[\d,]*)\]")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _tensor_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dims = [int(d) for d in m.group("dims").split(",") if d]
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[m.group("dt")]
    return total


def _parse_computations(hlo: str) -> dict:
    """computation name -> list of op lines.

    HLO computations are top-level blocks: a header at column 0 ending in
    '{' (params may contain nested tuple parens, so we only take the leading
    token as the name), indented op lines, and a closing '}' at column 0."""
    comps: dict[str, list[str]] = {}
    cur = None
    for raw in hlo.splitlines():
        if not raw:
            continue
        if not raw.startswith(" ") and raw.rstrip().endswith("{") and "->" in raw:
            head = raw.strip()
            if head.startswith("ENTRY"):
                head = head[len("ENTRY"):].strip()
            name = head.split("(", 1)[0].strip().lstrip("%").strip()
            cur = name
            comps[cur] = []
            continue
        if raw.startswith("}"):
            cur = None
            continue
        if cur is not None and "=" in raw:
            comps[cur].append(raw.strip())
    return comps


def _loop_multipliers(hlo: str, comps: dict, default_layers: int) -> dict:
    """computation name -> execution multiplier (product of enclosing loop
    trip counts)."""
    # find while ops: body=%comp; trip count via known_trip_count or induction
    # comparison constant when available.
    body_of: dict[str, tuple[str, int]] = {}   # body comp -> (parent comp, trips)
    for parent, lines in comps.items():
        for ln in lines:
            if " while(" not in ln and "while(" not in ln:
                continue
            mb = re.search(r"body=%?([\w.\-]+)", ln)
            if not mb:
                continue
            trips = None
            mt = re.search(r'known_trip_count[^\d]*(\d+)', ln)
            if mt:
                trips = int(mt.group(1))
            body_of[mb.group(1)] = (parent, trips)

    # also map called computations (fusion/call/conditional) to parent with x1
    called: dict[str, str] = {}
    for parent, lines in comps.items():
        for ln in lines:
            for mc in re.finditer(r"(?:calls|to_apply|body|condition|branch_computations)="
                                  r"[{%]*([\w.\-]+)", ln):
                called.setdefault(mc.group(1), parent)

    mult: dict[str, int] = {}

    def resolve(name: str, depth=0) -> int:
        if depth > 20:
            return 1
        if name in mult:
            return mult[name]
        if name in body_of:
            parent, trips = body_of[name]
            t = trips if trips else default_layers
            m = t * resolve(parent, depth + 1)
        elif name in called:
            m = resolve(called[name], depth + 1)
        else:
            m = 1
        mult[name] = m
        return m

    return {name: resolve(name) for name in comps}


def _op_shapes(lines: list[str], header: str | None = None) -> dict:
    """op name -> list of dims, from def lines within one computation."""
    shapes: dict[str, list[int]] = {}
    for ln in lines:
        m = re.match(r"(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
                     r"(?:bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|pred)"
                     r"\[([\d,]*)\]", ln)
        if m:
            shapes[m.group(1)] = [int(d) for d in m.group(2).split(",") if d]
    if header:
        # simple (non-tuple) params: "name: bf16[...]"
        for m in re.finditer(r"([\w.\-]+):\s*"
                             r"(bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|pred)"
                             r"\[([\d,]*)\]", header):
            shapes.setdefault(
                m.group(1), [int(d) for d in m.group(3).split(",") if d])
    return shapes


_DOT_RE = re.compile(
    r"=\s*(?:bf16|f16|f32|f64|s32|u32)\[([\d,]*)\][^=]*?\bdot\(%?([\w.\-]+),\s*%?([\w.\-]+)\)"
    r"(.*)$")


def dot_flops_loop_aware(hlo: str, default_layers: int) -> tuple[float, float]:
    f, _, cov = dot_stats_loop_aware(hlo, default_layers)
    return f, cov


def dot_stats_loop_aware(hlo: str, default_layers: int) -> tuple[float, float, float]:
    """(dot FLOPs, dot operand+output bytes, coverage) per device with loop
    trip counts.

    flops(dot) = 2 * prod(output dims) * prod(contracting dims).
    bytes(dot) = lhs + rhs + out tensor bytes — the matmul-operand HBM
    traffic, the principled roofline memory term (elementwise traffic is
    assumed fused).  Contracting sizes come from the operands' defs."""
    comps = _parse_computations(hlo)
    headers: dict[str, str] = {}
    for raw in hlo.splitlines():
        if not raw.startswith(" ") and raw.rstrip().endswith("{") and "->" in raw:
            head = raw.strip()
            if head.startswith("ENTRY"):
                head = head[len("ENTRY"):].strip()
            name = head.split("(", 1)[0].strip().lstrip("%").strip()
            headers[name] = raw
    mults = _loop_multipliers(hlo, comps, default_layers)
    total_f = total_b = 0.0
    n_dots = n_resolved = 0
    for comp, lines in comps.items():
        m = mults.get(comp, 1)
        shapes = _op_shapes(lines, headers.get(comp))
        for ln in lines:
            dm = _DOT_RE.search(ln)
            if not dm:
                continue
            n_dots += 1
            out_dims = [int(d) for d in dm.group(1).split(",") if d]
            lhs, rhs, rest = dm.group(2), dm.group(3), dm.group(4)
            k = None
            lm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
            if lm and lhs in shapes:
                dims = shapes[lhs]
                k = 1
                for i in (int(x) for x in lm.group(1).split(",") if x):
                    if i < len(dims):
                        k *= dims[i]
            if k is None:
                rm = re.search(r"rhs_contracting_dims=\{([\d,]*)\}", rest)
                if rm and rhs in shapes:
                    dims = shapes[rhs]
                    k = 1
                    for i in (int(x) for x in rm.group(1).split(",") if x):
                        if i < len(dims):
                            k *= dims[i]
            if k is None:
                continue
            n_resolved += 1
            out = 1
            for d in out_dims:
                out *= d
            total_f += 2.0 * out * k * m
            # operand/output bytes (assume 2 B storage for operands unless
            # the def says otherwise; output dtype from the dot line itself)
            nbytes = 0
            for opnd in (lhs, rhs):
                if opnd in shapes:
                    n = 1
                    for d in shapes[opnd]:
                        n *= d
                    nbytes += 2 * n
            nbytes += _tensor_bytes(ln.split("=", 1)[1][:80])
            total_b += nbytes * m
    coverage = n_resolved / n_dots if n_dots else 1.0
    return total_f, total_b, coverage


def bytes_loop_aware(hlo: str, default_layers: int) -> float:
    """Loop-aware HBM-traffic UPPER BOUND: every op (≈ fusion) output is
    written to HBM once per execution; consumer reads equal producer writes,
    so outputs are counted once.  Real TPU keeps many of these in
    VMEM/registers, so this bounds the memory term from above; cost_analysis'
    loop-unaware 'bytes accessed' bounds it from below.  Both are reported."""
    comps = _parse_computations(hlo)
    mults = _loop_multipliers(hlo, comps, default_layers)
    total = 0.0
    for comp, lines in comps.items():
        m = mults.get(comp, 1)
        for ln in lines:
            mm = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*((?:bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|pred)\[[\d,]*\])", ln)
            if mm:
                total += _tensor_bytes(mm.group(1)) * m
    return total


def collective_bytes_loop_aware(hlo: str, default_layers: int) -> dict:
    comps = _parse_computations(hlo)
    mults = _loop_multipliers(hlo, comps, default_layers)
    out: dict[str, dict] = {}
    for comp, lines in comps.items():
        m = mults.get(comp, 1)
        for ln in lines:
            km = re.search(
                r"=\s*([a-z0-9\[\],\s{}()]*?)\s*(" + "|".join(_COLL_KINDS) + r")(-start)?\(",
                ln)
            if not km:
                continue
            kind = km.group(2)
            nbytes = _tensor_bytes(ln.split("=", 1)[0]) or _tensor_bytes(ln)
            rec = out.setdefault(kind, {"count": 0, "bytes": 0})
            rec["count"] += m
            rec["bytes"] += m * nbytes
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float           # analytic 6*N*D (global)
    hlo_flops_global: float
    useful_fraction: float       # model_flops / hlo_flops
    bottleneck: str

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """compute term / max term: 1.0 = compute-bound at peak."""
        return self.compute_s / self.step_time_s if self.step_time_s else 0.0


def model_flops(cfg, shape) -> float:
    """Analytic useful FLOPs per step (global): 6*N_active*tokens for train,
    2*N_active*tokens for inference forward."""
    n_active = cfg.active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch * 1  # decode: one token
    return 2.0 * n_active * tokens


def analyze_record(rec: dict, cfg, shape, hlo: str | None = None) -> Roofline:
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    flops_dev = rec["flops_per_device"]
    bytes_dev = rec["bytes_per_device"]
    if hlo is not None:
        colls = collective_bytes_loop_aware(hlo, cfg.num_superblocks)
    else:
        colls = rec.get("collectives", {})
    coll_dev = sum(v["bytes"] for v in colls.values())
    mf = model_flops(cfg, shape)
    hlo_global = flops_dev * chips
    r = Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"], chips=chips,
        compute_s=flops_dev / PEAK_FLOPS,
        memory_s=bytes_dev / HBM_BW,
        collective_s=coll_dev / (ICI_BW * ICI_LINKS),
        model_flops=mf,
        hlo_flops_global=hlo_global,
        useful_fraction=mf / hlo_global if hlo_global else 0.0,
        bottleneck="",
    )
    terms = {"compute": r.compute_s, "memory": r.memory_s,
             "collective": r.collective_s}
    r.bottleneck = max(terms, key=terms.get)
    return r
