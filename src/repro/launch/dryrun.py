import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell
and extract the roofline terms from the compiled artifact.

The two lines above MUST run before any other import (jax locks the device
count on first init) — which is why this module must never be imported by
tests or benchmarks; run it as ``PYTHONPATH=src python -m repro.launch.dryrun``.

Usage:
  python -m repro.launch.dryrun                       # all 34 cells, both meshes
  python -m repro.launch.dryrun --arch kimi-k2-1t-a32b --shape train_4k
  python -m repro.launch.dryrun --multi-pod-only      # just the 512-chip pass
  python -m repro.launch.dryrun --out results/dryrun.json

Per cell it records: compile ok, memory_analysis (bytes/device),
cost_analysis FLOPs & bytes, and the collective-bytes breakdown parsed from
the optimized HLO — the inputs to EXPERIMENTS.md §Dry-run / §Roofline.
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax

from repro.configs.base import SHAPES, cells_for
from repro.launch.mesh import make_production_mesh, mesh_context
from repro.launch.steps import make_step
from repro.models import registry

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}
_COLL_RE = re.compile(
    r"(?P<shape>(?:\(|)[a-z0-9\[\],\s/{}]*?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.I)
_SHAPE_RE = re.compile(r"(?P<dt>bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|pred)"
                       r"\[(?P<dims>[\d,]*)\]")


def _tensor_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dims = [int(d) for d in m.group("dims").split(",") if d]
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[m.group("dt")]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-operand bytes of every collective op in the optimized HLO.

    Counts each op once (start/done fusion pairs deduped by line) keyed by
    collective kind.  Bytes are per-PARTITION (SPMD module is per-device).
    """
    out: dict = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.search(
            r"=\s*([a-z0-9\[\],\s{}]*?)\s*"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
            r"(-start)?\(", line)
        if not m:
            continue
        lhs = line.split("=", 1)[0]
        kind = m.group(2)
        nbytes = _tensor_bytes(lhs) or _tensor_bytes(m.group(1))
        if nbytes == 0:
            # fall back: first shape on the line
            nbytes = _tensor_bytes(line)
        rec = out.setdefault(kind, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += nbytes
    return out


# ---------------------------------------------------------------------------
# single-cell dry run
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             stream_mode: str | None = None, verbose: bool = True) -> dict:
    cfg = registry.get_config(arch)
    if stream_mode:
        from repro.core.streamer import StreamSettings
        cfg = cfg.with_(stream=StreamSettings(mode=stream_mode,
                                              ring_depth=cfg.stream.ring_depth))
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "stream_mode": cfg.stream.mode,
        "ok": False,
    }
    t0 = time.time()
    try:
        with mesh_context(mesh):
            bundle = make_step(cfg, mesh, shape)
            lowered = bundle.fn.lower(*bundle.input_specs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            ma = compiled.memory_analysis()
            ca = compiled.cost_analysis() or {}
            hlo = compiled.as_text()
            colls = collective_bytes(hlo)
            # loop-aware accounting: collectives/dots inside the scan-over-
            # layers while body execute num_superblocks times but appear once
            # in the HLO text (launch/roofline.py).
            from repro.launch import roofline as rl
            layers = cfg.num_superblocks
            colls_la = rl.collective_bytes_loop_aware(hlo, layers)
            flops_la, dot_bytes_la, dot_cov = rl.dot_stats_loop_aware(hlo, layers)
            bytes_la = rl.bytes_loop_aware(hlo, layers)
            rec.update(
                ok=True,
                lower_s=round(t_lower, 2),
                compile_s=round(t_compile, 2),
                flops_per_device=float(ca.get("flops", 0.0)),
                bytes_per_device=float(ca.get("bytes accessed", 0.0)),
                flops_per_device_loop_aware=flops_la,
                dot_coverage=round(dot_cov, 4),
                bytes_per_device_loop_aware=bytes_la,
                dot_bytes_per_device_loop_aware=dot_bytes_la,
                argument_bytes=int(ma.argument_size_in_bytes),
                output_bytes=int(ma.output_size_in_bytes),
                temp_bytes=int(ma.temp_size_in_bytes),
                alias_bytes=int(ma.alias_size_in_bytes),
                collectives={k: dict(v) for k, v in colls.items()},
                collective_bytes_per_device=sum(v["bytes"] for v in colls.values()),
                collectives_loop_aware={k: dict(v) for k, v in colls_la.items()},
                collective_bytes_per_device_loop_aware=sum(
                    v["bytes"] for v in colls_la.values()),
                generated_code_bytes=int(ma.generated_code_size_in_bytes),
            )
            if verbose:
                hbm = (rec["argument_bytes"] + rec["temp_bytes"]
                       + rec["output_bytes"] - rec["alias_bytes"])
                print(f"  ok  lower={t_lower:5.1f}s compile={t_compile:6.1f}s "
                      f"flops/dev={rec['flops_per_device']:.3e} "
                      f"hbm/dev={hbm/2**30:6.2f}GiB "
                      f"coll/dev={rec['collective_bytes_per_device']/2**30:7.3f}GiB",
                      flush=True)
    except Exception as e:  # noqa: BLE001 — record failures, keep sweeping
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"  FAIL {type(e).__name__}: {str(e)[:200]}", flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch (default: all)")
    ap.add_argument("--shape", default=None, help="single shape (default: assigned)")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--stream-mode", default=None,
                    choices=["resident", "insitu", "naive_pp", "gpp"])
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else list(registry.ARCH_NAMES)
    meshes = []
    if not args.multi_pod_only:
        meshes.append(False)
    if not args.single_pod_only:
        meshes.append(True)

    results = []
    for arch in archs:
        cfg = registry.get_config(arch)
        shapes = [args.shape] if args.shape else cells_for(cfg)
        for shape_name in shapes:
            for mp in meshes:
                print(f"[{arch} x {shape_name} x {'2x16x16' if mp else '16x16'}"
                      f"{' x ' + args.stream_mode if args.stream_mode else ''}]",
                      flush=True)
                results.append(run_cell(arch, shape_name, mp,
                                        stream_mode=args.stream_mode))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    mode = "a" if os.environ.get("DRYRUN_APPEND") else "w"
    existing = []
    if mode == "a" and os.path.exists(args.out):
        existing = json.load(open(args.out))
    with open(args.out, "w") as f:
        json.dump(existing + results, f, indent=1)
    n_ok = sum(r["ok"] for r in results)
    print(f"\n{n_ok}/{len(results)} cells compiled; results -> {args.out}")
    return 0 if n_ok == len(results) else 1


if __name__ == "__main__":
    sys.exit(main())
