"""Serving driver: batched request decode through the paged-KV engine
(chunked prefill + continuous batching), with the dense-cache engine as the
recurrent-arch fallback / comparison baseline.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \\
      --requests 6 --max-new 16
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--engine", choices=("auto", "paged", "dense"),
                    default="auto")
    ap.add_argument("--block-size", type=int, default=0,
                    help="paged-KV block size (0 = cfg.serve_block_size)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="prefill chunk tokens (0 = plan_serve_chunk)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--paged-attn", choices=("auto", "pallas", "interpret",
                                             "ref"), default="auto",
                    help="paged-attention read path: pallas streams KV "
                         "blocks through the VMEM-ring kernel, ref gathers "
                         "pools, interpret runs the kernel on CPU")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="radix-tree shared-prefix KV reuse: admission maps "
                         "previously computed prompt-prefix blocks into the "
                         "lane's tables and prefill skips the matched "
                         "chunks (default: cfg.prefix_cache)")
    ap.add_argument("--prefix-cache-blocks", type=int, default=None,
                    help="cap on blocks the prefix index may pin "
                         "(0 = unbounded; default: cfg.prefix_cache_blocks)")
    ap.add_argument("--speculation", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="speculative decoding (paged engine): draft tokens "
                         "are scored in one batched verify pass of "
                         "draft_len+1 tokens per lane, amortizing the "
                         "streamed weight working set; the output stream is "
                         "token-for-token identical with speculation off "
                         "(default: cfg.speculation)")
    ap.add_argument("--draft-len", type=int, default=0,
                    help="max draft tokens per lane per verify step "
                         "(0 = cfg.draft_len; the verify shape is "
                         "(slots, draft_len+1))")
    ap.add_argument("--draft-source", choices=("self", "model"),
                    default="self",
                    help="draft proposals: 'self' mines prompt-lookup "
                         "n-grams from the lane's history and the prefix "
                         "radix tree (no extra weights streamed); 'model' "
                         "rolls out --draft-model greedily")
    ap.add_argument("--draft-model", default=None,
                    help="registry arch name of a small draft model for "
                         "--draft-source model (loads its smoke config "
                         "when --smoke is set)")
    ap.add_argument("--obs", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="serving telemetry (repro.obs): request/kernel "
                         "trace spans, TTFT/TPOT histograms, step wall "
                         "times in the ledger (default: cfg.obs; implied "
                         "by --trace-out/--metrics-out)")
    ap.add_argument("--trace-out", default=None,
                    help="write the Chrome/Perfetto trace-event JSON here "
                         "(load at https://ui.perfetto.dev)")
    ap.add_argument("--metrics-out", default=None,
                    help="append one JSONL metrics snapshot here (TTFT/"
                         "TPOT p50/p99 summaries + ledger totals + "
                         "predicted-vs-measured utilization)")
    ap.add_argument("--trace-capacity", type=int, default=0,
                    help="trace ring-buffer capacity in events "
                         "(0 = cfg.obs_trace_capacity; oldest events drop "
                         "once full, counted in otherData.dropped_events)")
    ap.add_argument("--metrics-retention", type=int, default=None,
                    help="per-step ledger rows kept in memory (None = "
                         "cfg.metrics_retention, 0 = unbounded; evicted "
                         "rows roll up so totals stay lifetime-exact)")
    args = ap.parse_args(argv)
    obs = args.obs
    if obs is None and (args.trace_out or args.metrics_out):
        obs = True

    import jax
    import numpy as np

    from repro.models import registry
    from repro.models import transformer as tf
    from repro.serving import (DenseServingEngine, ServeConfig, ServingEngine,
                               make_engine)

    cfg = registry.get_config(args.arch, smoke=args.smoke)
    if cfg.input_mode != "tokens":
        raise SystemExit(f"{args.arch} takes embedding inputs; serve the token "
                         "archs (stub frontends have no tokenizer)")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    serve = ServeConfig(
        slots=args.slots, max_len=args.max_len, temperature=args.temperature,
        seed=args.seed, block_size=args.block_size,
        prefill_chunk=args.prefill_chunk,
        paged_attn_kernel=args.paged_attn,
        prefix_cache=args.prefix_cache,
        prefix_cache_blocks=args.prefix_cache_blocks,
        speculation=args.speculation, draft_len=args.draft_len,
        draft_source=args.draft_source,
        obs=obs, trace_capacity=args.trace_capacity,
        metrics_retention=args.metrics_retention)
    draft_model = None
    if args.draft_model:
        dcfg = registry.get_config(args.draft_model, smoke=args.smoke)
        draft_model = (dcfg, tf.init_params(dcfg, jax.random.PRNGKey(1)))
    if args.engine == "paged":
        engine = ServingEngine(cfg, params, serve, draft_model=draft_model)
    elif args.engine == "dense":
        engine = DenseServingEngine(cfg, params, serve)
    else:
        engine = make_engine(cfg, params, serve, draft_model=draft_model)
    kind = type(engine).__name__

    rng = np.random.default_rng(0)
    rids = []
    t0 = time.time()
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=rng.integers(4, 12)).tolist()
        rids.append(engine.submit(prompt, max_new_tokens=args.max_new))
    results = engine.run()
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in results.values())
    for rid in rids:
        print(f"request {rid}: {len(results[rid])} tokens -> {results[rid][:8]}...")
    print(f"{len(rids)} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s aggregate, {kind})")
    if engine.metrics:
        peak_blocks = max(m.get("blocks_in_use", 0) for m in engine.metrics)
        print(f"steps={len(engine.metrics)} tokens/step_cov="
              f"{engine.flatness_cov():.3f} peak_blocks={peak_blocks} "
              f"traces={getattr(engine, 'trace_counts', {})}")
        if getattr(engine, "prefix", None) is not None:
            hit_toks = sum(m.get("prefix_hit_tokens", 0)
                           for m in engine.metrics)
            print(f"prefix_cache: hit_rate={engine.prefix_hit_rate():.2f} "
                  f"hit_tokens={hit_toks} "
                  f"blocks_held={engine.prefix.blocks_held}")
        if getattr(engine, "draft_len", 0):
            drafted = sum(m.get("drafted_tokens", 0) for m in engine.metrics)
            accepted = sum(m.get("accepted_tokens", 0)
                           for m in engine.metrics)
            print(f"speculation: drafted={drafted} accepted={accepted} "
                  f"acceptance_rate={engine.acceptance_rate():.2f} "
                  f"draft_len={engine.draft_len} "
                  f"source={engine.draft_source}")
    if engine.obs.enabled:
        req = engine.obs.requests.summary()
        ttft, tpot = req["ttft"], req["tpot"]
        print(f"ttft_s: p50={ttft['p50']:.4f} p99={ttft['p99']:.4f} "
              f"(n={ttft['count']})  tpot_s: p50={tpot['p50']:.4f} "
              f"p99={tpot['p99']:.4f} (n={tpot['count']})")
        util = engine.metrics.utilization_report()
        print(f"bw_utilization: measured="
              f"{util['measured_bw_utilization']:.3f} predicted="
              f"{util['predicted_bw_utilization']:.3f} "
              f"cov={util['hbm_bytes_per_step_cov']:.3f}")
        if args.trace_out:
            engine.obs.write_trace(args.trace_out)
            print(f"trace: {args.trace_out} "
                  f"({len(engine.obs.trace)} events, "
                  f"{engine.obs.trace.dropped} dropped)")
        if args.metrics_out:
            engine.obs.write_metrics(
                args.metrics_out, extra={"ledger": engine.metrics.summary()})
            print(f"metrics: {args.metrics_out}")
    return results


if __name__ == "__main__":
    main()
