"""Generate the EXPERIMENTS.md roofline table from dry-run JSON results.

  PYTHONPATH=src python -m repro.launch.report results/dryrun_singlepod.json
"""
from __future__ import annotations

import json
import sys

from repro.configs.base import SHAPES
from repro.launch.roofline import HBM_BW, ICI_BW, ICI_LINKS, PEAK_FLOPS, model_flops
from repro.models import registry


def rows_from(path: str) -> list[dict]:
    out = []
    for rec in json.load(open(path)):
        if not rec["ok"]:
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "mesh": rec["mesh"], "error": rec.get("error")})
            continue
        cfg = registry.get_config(rec["arch"])
        shape = SHAPES[rec["shape"]]
        chips = 512 if rec["mesh"] == "2x16x16" else 256
        fl = rec.get("flops_per_device_loop_aware") or rec["flops_per_device"]
        by_lo = rec["bytes_per_device"]
        # memory term: matmul-operand traffic (loop-aware); falls back to the
        # all-op-output estimate for older records
        by_hi = rec.get("dot_bytes_per_device_loop_aware") or rec.get(
            "bytes_per_device_loop_aware", by_lo)
        co = rec.get("collective_bytes_per_device_loop_aware",
                     rec["collective_bytes_per_device"])
        compute_s = fl / PEAK_FLOPS
        mem_lo_s = by_lo / HBM_BW
        mem_hi_s = by_hi / HBM_BW
        coll_s = co / (ICI_BW * ICI_LINKS)
        mf = model_flops(cfg, shape)
        terms = {"compute": compute_s, "memory": mem_hi_s, "collective": coll_s}
        bottleneck = max(terms, key=terms.get)
        step = max(terms.values())
        out.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "chips": chips,
            "compute_s": compute_s, "memory_lo_s": mem_lo_s,
            "memory_hi_s": mem_hi_s, "collective_s": coll_s,
            "bottleneck": bottleneck,
            "model_flops": mf,
            "hlo_flops_global": fl * chips,
            "useful_frac": mf / (fl * chips) if fl else 0.0,
            "roofline_frac": compute_s / step if step else 0.0,
            "hbm_gib": (rec["argument_bytes"] + rec["temp_bytes"]
                        + rec["output_bytes"] - rec["alias_bytes"]) / 2**30,
            "stream_mode": rec.get("stream_mode", "resident"),
        })
    return out


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s (lo–hi) | collective s "
           "| bottleneck | MODEL/HLO flops | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if "error" in r and r.get("error"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — "
                         f"| FAILED | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.4f} "
            f"| {r['memory_lo_s']:.4f}–{r['memory_hi_s']:.4f} "
            f"| {r['collective_s']:.4f} "
            f"| {r['bottleneck']} "
            f"| {r['useful_frac']:.2f} "
            f"| {r['roofline_frac']:.2f} |")
    return hdr + "\n".join(lines)


def main(argv=None):
    paths = (argv or sys.argv[1:]) or ["results/dryrun_singlepod.json"]
    for p in paths:
        rows = rows_from(p)
        print(f"\n### {p}\n")
        print(markdown_table(rows))


if __name__ == "__main__":
    main()
