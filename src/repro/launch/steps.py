"""Jitted train/prefill/decode steps with production shardings.

This is the glue the launcher, dry-run, and benchmarks share: given
(arch config, mesh, shape), build the step function plus the
ShapeDtypeStruct input specs and in/out shardings, ready for either
`.lower().compile()` (dry-run) or real execution (examples/tests on a host
mesh).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import make_batch_specs
from repro.dist import sharding as shd
from repro.launch.mesh import dp_axes
from repro.models import transformer as tf
from repro.optim import adamw as opt
from repro.optim import adafactor as adaf

Pytree = Any


@dataclasses.dataclass
class StepBundle:
    fn: Any                      # jitted step function
    input_specs: tuple           # positional ShapeDtypeStructs for .lower()
    arg_shardings: tuple
    out_shardings: Any
    meta: dict


HBM_RESIDENT_BUDGET = 6e9  # bytes of TP-sharded weights we allow resident


def _rules_for(cfg: ModelConfig, mesh, serve: bool = False) -> shd.ShardRules:
    # ZeRO-3 over ("pod","data") only when the params can't afford pod
    # replication (1T-class models); small models keep FSDP intra-pod so no
    # per-layer gather crosses the (slower) inter-pod links.
    fsdp_axes = ("data",)
    tp = mesh.shape.get("model", 1)
    if "pod" in mesh.shape:
        per_dev_replicated = cfg.total_params() * 2 / (16 * tp)
        if per_dev_replicated > 4e9:
            fsdp_axes = ("pod", "data")
    # Serving: keep weights resident (TP-only) when they fit — FSDP would
    # all-gather the whole model per decoded token.  Only 1T-class models
    # must stay sharded (and are the paper's streaming case).
    fsdp = True
    if serve and cfg.total_params() * 2 / tp < HBM_RESIDENT_BUDGET:
        fsdp = False
    return shd.ShardRules(
        tp_axis="model",
        fsdp_axes=fsdp_axes,
        dp_axes=dp_axes(mesh),
        fsdp=fsdp,
        moe_ep_mode=cfg.moe_ep_mode if cfg.num_experts else "tp",
        moe_serve_resident=bool(serve and cfg.moe_serve_resident),
    )


def _stream_pspecs(cfg: ModelConfig, mesh, rules):
    """(shard_specs, full_specs) for ONE superblock's weights (streamer args)."""
    one = {
        f"b{i}": tf.block_specs(cfg, k)
        for i, k in enumerate(cfg.pattern)
        if not k.startswith("shared_attn")
    }
    return (
        shd.sharded_pspecs_one_layer(one, mesh, rules),
        shd.gathered_pspecs(one, mesh, rules),
    )


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, mesh, shape: ShapeConfig,
                    optc: opt.AdamWConfig | None = None) -> StepBundle:
    optc = optc or opt.AdamWConfig()
    rules = _rules_for(cfg, mesh)

    pspecs = tf.param_specs(cfg)
    p_psp = shd.param_pspecs(pspecs, mesh, rules)
    if cfg.optimizer == "adafactor":
        opt_specs = adaf.adafactor_state_specs(pspecs)
        # factors inherit the matching dims of the param sharding
        def _factor_psp(psp_leaf_tree):
            def f(path, s):
                return P(*([None] * len(s.shape)))
            return jax.tree_util.tree_map_with_path(f, opt_specs["factors"])
        opt_psp = {"factors": _factor_psp(p_psp), "count": P()}
    else:
        opt_specs = opt.adamw_state_specs(pspecs)
        opt_psp = {"mu": p_psp, "nu": p_psp, "count": P()}
    batch_specs = make_batch_specs(cfg, shape.global_batch, shape.seq_len,
                                   dtype=cfg.dtype)
    b_psp = {
        k: P(rules.dp_axes, *([None] * (len(v.shape) - 1)))
        for k, v in batch_specs.items()
    }

    stream_shard, stream_full = (
        _stream_pspecs(cfg, mesh, rules) if cfg.stream.mode != "resident"
        else (None, None)
    )

    act_pspec = P(rules.dp_axes, None, None)

    def train_step(params, opt_state, batch, step):
        def loss(p):
            return tf.loss_fn(p, cfg, batch, mesh=mesh,
                              shard_specs=stream_shard, full_specs=stream_full,
                              act_pspec=act_pspec)

        loss_val, grads = jax.value_and_grad(loss)(params)
        lr = opt.cosine_lr(step, peak=optc.lr, warmup=200, total=10_000)
        if cfg.optimizer == "adafactor":
            params, opt_state, metrics = adaf.adafactor_update(
                adaf.AdafactorConfig(lr=optc.lr), grads, opt_state, params, lr=lr)
        else:
            params, opt_state, metrics = opt.adamw_update(
                optc, grads, opt_state, params, lr=lr)
        metrics["loss"] = loss_val
        return params, opt_state, metrics

    named = functools.partial(NamedSharding, mesh)
    arg_shardings = (
        jax.tree.map(named, p_psp, is_leaf=lambda x: isinstance(x, P)),
        jax.tree.map(named, opt_psp, is_leaf=lambda x: isinstance(x, P)),
        jax.tree.map(named, b_psp, is_leaf=lambda x: isinstance(x, P)),
        NamedSharding(mesh, P()),
    )
    out_shardings = (arg_shardings[0], arg_shardings[1], None)
    fn = jax.jit(
        train_step,
        in_shardings=arg_shardings,
        out_shardings=out_shardings,
        donate_argnums=(0, 1),
    )
    input_specs = (
        pspecs, opt_specs, batch_specs, jax.ShapeDtypeStruct((), jnp.int32),
    )
    return StepBundle(fn, input_specs, arg_shardings, out_shardings,
                      meta={"rules": rules, "param_pspecs": p_psp})


# ---------------------------------------------------------------------------
# serve steps (prefill / decode)
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, mesh, shape: ShapeConfig) -> StepBundle:
    rules = _rules_for(cfg, mesh, serve=True)
    pspecs = tf.param_specs(cfg)
    p_psp = shd.param_pspecs(pspecs, mesh, rules)
    batch_specs = make_batch_specs(cfg, shape.global_batch, shape.seq_len,
                                   dtype=cfg.dtype)
    batch_specs.pop("labels")
    dp = rules.dp_axes
    bsz = shape.global_batch
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    bdim = dp if bsz % dp_size == 0 else None
    b_psp = {k: P(bdim, *([None] * (len(v.shape) - 1)))
             for k, v in batch_specs.items()}

    act_pspec = P(bdim, None, None)

    def prefill_step(params, batch):
        return tf.prefill(params, cfg, batch, max_len=shape.seq_len,
                          mesh=mesh, act_pspec=act_pspec)

    named = functools.partial(NamedSharding, mesh)
    arg_shardings = (
        jax.tree.map(named, p_psp, is_leaf=lambda x: isinstance(x, P)),
        jax.tree.map(named, b_psp, is_leaf=lambda x: isinstance(x, P)),
    )
    cache_sp = tf.cache_specs(cfg, bsz, shape.seq_len)
    cache_psp = shd.cache_pspecs(cache_sp, mesh, rules, bsz)
    out_shardings = (None, jax.tree.map(named, cache_psp,
                                        is_leaf=lambda x: isinstance(x, P)))
    fn = jax.jit(prefill_step, in_shardings=arg_shardings,
                 out_shardings=out_shardings)
    return StepBundle(fn, (pspecs, batch_specs), arg_shardings, out_shardings,
                      meta={"rules": rules, "cache_pspecs": cache_psp})


def make_decode_step(cfg: ModelConfig, mesh, shape: ShapeConfig) -> StepBundle:
    """One-token decode with a seq_len KV cache (the decode_* cells)."""
    if cfg.num_experts and cfg.num_experts % mesh.shape.get("model", 1) == 0:
        # decode token counts are tiny: keep experts RESIDENT (E:model x
        # d_ff:data) instead of streaming 2 TB of weights per token
        cfg = cfg.with_(moe_serve_resident=True)
    rules = _rules_for(cfg, mesh, serve=True)
    pspecs = tf.param_specs(cfg)
    p_psp = shd.param_pspecs(pspecs, mesh, rules)
    bsz = shape.global_batch
    cache_sp = tf.cache_specs(cfg, bsz, shape.seq_len)
    cache_psp = shd.cache_pspecs(cache_sp, mesh, rules, bsz)

    dp = rules.dp_axes
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    bdim = dp if bsz % dp_size == 0 else None

    if cfg.input_mode == "tokens":
        tok_spec = jax.ShapeDtypeStruct((bsz, 1), jnp.int32)
        tok_psp = P(bdim, None)
    else:
        tok_spec = jax.ShapeDtypeStruct((bsz, 1, cfg.d_model), cfg.jdtype)
        tok_psp = P(bdim, None, None)

    enc_spec = None
    if cfg.encoder_tokens:
        enc_spec = jax.ShapeDtypeStruct(
            (bsz, cfg.encoder_tokens, cfg.d_model), cfg.jdtype)

    def decode(params, toks, caches, pos, enc=None):
        return tf.decode_step(params, cfg, toks, caches, pos, enc=enc)

    named = functools.partial(NamedSharding, mesh)
    arg_shardings = [
        jax.tree.map(named, p_psp, is_leaf=lambda x: isinstance(x, P)),
        NamedSharding(mesh, tok_psp),
        jax.tree.map(named, cache_psp, is_leaf=lambda x: isinstance(x, P)),
        NamedSharding(mesh, P()),
    ]
    input_specs = [pspecs, tok_spec, cache_sp,
                   jax.ShapeDtypeStruct((), jnp.int32)]
    if enc_spec is not None:
        arg_shardings.append(NamedSharding(mesh, P(bdim, None, None)))
        input_specs.append(enc_spec)
    out_shardings = (None, arg_shardings[2])
    fn = jax.jit(decode, in_shardings=tuple(arg_shardings),
                 out_shardings=out_shardings, donate_argnums=(2,))
    return StepBundle(fn, tuple(input_specs), tuple(arg_shardings),
                      out_shardings, meta={"rules": rules})


def make_step(cfg: ModelConfig, mesh, shape: ShapeConfig) -> StepBundle:
    if shape.kind == "train":
        return make_train_step(cfg, mesh, shape)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, mesh, shape)
    if shape.kind == "decode":
        return make_decode_step(cfg, mesh, shape)
    raise ValueError(shape.kind)
