"""Production mesh builders + jax-version compat shims.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required by the dry-run contract.

The explicit-mesh API (`jax.sharding.AxisType`, `jax.set_mesh`) landed after
jax 0.4.x; `make_mesh_compat` / `mesh_context` paper over the difference so
the launchers and the distributed tests run on both: on 0.4.x the mesh is
built without axis types (Auto is the 0.4.x default semantics anyway) and
the ambient-mesh context is the `Mesh` context manager itself — explicit
`NamedSharding`s carry the mesh, so nothing downstream depends on the
ambient registry being the new one.
"""
from __future__ import annotations

import jax

JAX_HAS_EXPLICIT_MESH = (hasattr(jax.sharding, "AxisType")
                         and hasattr(jax, "set_mesh"))


def make_mesh_compat(shape: "tuple[int, ...]", axes: "tuple[str, ...]"):
    """jax.make_mesh with Auto axis types where the API exists, plain
    jax.make_mesh on 0.4.x (same Auto/GSPMD semantics)."""
    if JAX_HAS_EXPLICIT_MESH:
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def mesh_context(mesh):
    """Ambient-mesh context: `jax.set_mesh` when available, otherwise the
    Mesh object itself (a context manager on 0.4.x)."""
    return jax.set_mesh(mesh) if JAX_HAS_EXPLICIT_MESH else mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) ("data", "model") = 256 chips (TPU v5e pod).
    Multi-pod: (2, 16, 16) ("pod", "data", "model") = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(data: int = 2, model: int = 2):
    """Small mesh over forced host devices (tests/examples on CPU)."""
    n = data * model
    if len(jax.devices()) < n:
        raise RuntimeError(
            f"need {n} devices; set XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            "before importing jax"
        )
    return make_mesh_compat((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    """Batch-sharding axes for the given mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
