"""Production mesh builders.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required by the dry-run contract.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) ("data", "model") = 256 chips (TPU v5e pod).
    Multi-pod: (2, 16, 16) ("pod", "data", "model") = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(data: int = 2, model: int = 2):
    """Small mesh over forced host devices (tests/examples on CPU)."""
    n = data * model
    if len(jax.devices()) < n:
        raise RuntimeError(
            f"need {n} devices; set XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            "before importing jax"
        )
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def dp_axes(mesh) -> tuple[str, ...]:
    """Batch-sharding axes for the given mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
