"""AdamW with global-norm clipping and cosine schedule, pure JAX.

Optimizer state inherits the params' sharding (specs mirror the param tree),
so with FSDP-sharded params the moments are ZeRO-sharded for free.  Moments
are f32 regardless of param dtype (bf16-safe).  Gradient compression option:
`compress="bf16"` casts gradients before the (XLA-inserted) all-reduce —
halves gradient collective bytes at the usual negligible quality cost.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    compress: str | None = None     # None | "bf16" gradient compression


def adamw_init(params: Pytree) -> Pytree:
    """Moment state (f32) shaped like params; count is a scalar."""
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_state_specs(param_specs: Pytree) -> Pytree:
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return {
        "mu": jax.tree.map(f32, param_specs),
        "nu": jax.tree.map(f32, param_specs),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }


def global_norm(tree: Pytree) -> jnp.ndarray:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)
    ))


def adamw_update(cfg: AdamWConfig, grads: Pytree, state: Pytree, params: Pytree,
                 lr: jnp.ndarray | float | None = None):
    """Returns (new_params, new_state, metrics)."""
    if cfg.compress == "bf16":
        grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)

    count = state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    lr_t = jnp.asarray(cfg.lr if lr is None else lr, jnp.float32)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      state["mu"], grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                      state["nu"], grads)

    def upd(p, m, v):
        mh = m / b1c
        vh = v / b2c
        step = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr_t * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "count": count}, {
        "grad_norm": gnorm, "lr": lr_t,
    }


def cosine_lr(step: jnp.ndarray, *, peak: float, warmup: int, total: int,
              floor_frac: float = 0.1) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = peak * s / max(1, warmup)
    prog = jnp.clip((s - warmup) / max(1, total - warmup), 0.0, 1.0)
    cos = peak * (floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(math.pi * prog)))
    return jnp.where(s < warmup, warm, cos)
