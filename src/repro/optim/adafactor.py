"""Adafactor (Shazeer & Stern 2018): factored second moments, no first moment
by default — the production optimizer for models whose AdamW state cannot fit
HBM (here: kimi-k2's 1T params on 16 GB/chip pods; T5/PaLM lineage).

State per >=2D weight: row factor (prod of leading dims,) + col factor
(last dim,) in f32 — ~(r+c)/(r*c) of AdamW's 2x f32.  1D params fall back to
unfactored second moment.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-3
    decay: float = 0.8            # beta2_t = 1 - t^-decay
    eps1: float = 1e-30
    eps2: float = 1e-3
    clip_threshold: float = 1.0
    weight_decay: float = 0.0


def _factored(shape) -> bool:
    return len(shape) >= 2


def adafactor_init(params: Pytree) -> Pytree:
    def st(p):
        if _factored(p.shape):
            row = jnp.zeros(p.shape[:-1], jnp.float32)   # reduce over last dim
            col = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return {"vr": row, "vc": col}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"factors": jax.tree.map(st, params), "count": jnp.zeros((), jnp.int32)}


def adafactor_state_specs(param_specs: Pytree) -> Pytree:
    def st(s):
        if _factored(s.shape):
            return {
                "vr": jax.ShapeDtypeStruct(s.shape[:-1], jnp.float32),
                "vc": jax.ShapeDtypeStruct(s.shape[:-2] + s.shape[-1:], jnp.float32),
            }
        return {"v": jax.ShapeDtypeStruct(s.shape, jnp.float32)}
    return {
        "factors": jax.tree.map(st, param_specs),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }


def adafactor_update(cfg: AdafactorConfig, grads: Pytree, state: Pytree,
                     params: Pytree, lr=None):
    count = state["count"] + 1
    t = count.astype(jnp.float32)
    beta2 = 1.0 - t ** (-cfg.decay)
    lr_t = jnp.asarray(cfg.lr if lr is None else lr, jnp.float32)

    def upd(p, g, st):
        g = g.astype(jnp.float32)
        g2 = g * g + cfg.eps1
        if _factored(p.shape):
            vr = beta2 * st["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * st["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            # v_hat ~ vr vc / mean(vr)
            denom = jnp.mean(vr, axis=-1, keepdims=True)
            vhat = (vr[..., None] * vc[..., None, :]
                    / jnp.maximum(denom[..., None], cfg.eps1))
            u = g * jax.lax.rsqrt(jnp.maximum(vhat, cfg.eps1))
            new_st = {"vr": vr, "vc": vc}
        else:
            v = beta2 * st["v"] + (1 - beta2) * g2
            u = g * jax.lax.rsqrt(jnp.maximum(v, cfg.eps1))
            new_st = {"v": v}
        # update clipping (RMS(u) <= clip_threshold)
        rms = jnp.sqrt(jnp.mean(u * u))
        u = u / jnp.maximum(1.0, rms / cfg.clip_threshold)
        scale = jnp.maximum(cfg.eps2, jnp.sqrt(jnp.mean(
            p.astype(jnp.float32) ** 2)))
        newp = (p.astype(jnp.float32) - lr_t * scale * u
                - lr_t * cfg.weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), new_st

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state["factors"])
    outs = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_factors = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_params, {"factors": new_factors, "count": count}, {
        "lr": lr_t, "grad_norm": jnp.sqrt(sum(
            jnp.sum(g.astype(jnp.float32) ** 2) for g in flat_g)),
    }
