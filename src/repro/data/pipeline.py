"""Deterministic sharded token pipeline.

Synthetic-corpus LM data (Zipfian token draws with a fixed PRNG lineage) with
the properties a real fleet pipeline needs:

  * deterministic resume: batch i depends only on (seed, i) — a restarted
    job re-materializes the exact stream from the checkpointed step, which is
    the straggler/fault story for input data (no shared queue state to lose);
  * host sharding: each host materializes only its slice of the global batch
    (shard_index / num_shards), matching the ("pod","data") batch sharding;
  * double-buffered host prefetch (thread) to overlap H2D with step compute;
  * modality stubs: `embeds`/`enc` streams for the audio/vlm archs.
"""
from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np

import jax

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    batch: int = 8                 # per-host batch
    seq_len: int = 128
    shard_index: int = 0
    num_shards: int = 1
    prefetch: int = 2


def _zipf_tokens(rng: np.random.Generator, shape, vocab: int) -> np.ndarray:
    """Zipf-ish token draws (realistic rank-frequency) clipped to vocab."""
    z = rng.zipf(1.3, size=shape)
    return (z % vocab).astype(np.int32)


class TokenPipeline:
    """Iterator of host-local batches; deterministic in (seed, step, shard)."""

    def __init__(self, cfg: ModelConfig, data: DataConfig):
        self.cfg = cfg
        self.data = data
        self._q: queue.Queue = queue.Queue(maxsize=max(1, data.prefetch))
        self._step = 0
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    # -- deterministic batch materialization --
    def batch_at(self, step: int) -> dict:
        d, c = self.data, self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([d.seed, step, d.shard_index])
        )
        tokens = _zipf_tokens(rng, (d.batch, d.seq_len + 1), c.vocab_size)
        out = {"labels": tokens[:, 1:]}
        if c.input_mode == "tokens":
            out["tokens"] = tokens[:, :-1]
        else:
            out["embeds"] = rng.standard_normal(
                (d.batch, d.seq_len, c.d_model), dtype=np.float32)
        if c.encoder_tokens:
            out["enc"] = rng.standard_normal(
                (d.batch, c.encoder_tokens, c.d_model), dtype=np.float32)
        return out

    # -- prefetching iterator --
    def _worker(self, start_step: int):
        s = start_step
        while not self._stop.is_set():
            try:
                self._q.put(self.batch_at(s), timeout=0.1)
                s += 1
            except queue.Full:
                continue

    def start(self, start_step: int = 0):
        self._step = start_step
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._worker, args=(start_step,), daemon=True)
        self._thread.start()
        return self

    def __next__(self) -> dict:
        if self._thread is None:
            b = self.batch_at(self._step)
        else:
            b = self._q.get()
        self._step += 1
        return b

    def __iter__(self):
        return self

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=1.0)
            self._thread = None


def make_batch_specs(cfg: ModelConfig, global_batch: int, seq_len: int,
                     dtype="bfloat16") -> dict:
    """ShapeDtypeStruct stand-ins for a global batch (dry-run input_specs)."""
    import jax.numpy as jnp
    i32 = jnp.int32
    out = {"labels": jax.ShapeDtypeStruct((global_batch, seq_len), i32)}
    if cfg.input_mode == "tokens":
        out["tokens"] = jax.ShapeDtypeStruct((global_batch, seq_len), i32)
    else:
        out["embeds"] = jax.ShapeDtypeStruct(
            (global_batch, seq_len, cfg.d_model), jnp.dtype(dtype))
    if cfg.encoder_tokens:
        out["enc"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.encoder_tokens, cfg.d_model), jnp.dtype(dtype))
    return out
