"""Fault-tolerant checkpointing: atomic per-host shard files + manifest,
step-granular resume, elastic re-sharding.

Layout:  <dir>/step_<n>/
            manifest.json          {step, mesh_shape, tree structure, hashes}
            shard_<host>.npz       host-local param/optim leaves (flattened)
            _COMMITTED             written last: a step dir without it is
                                   garbage from a mid-write failure and is
                                   ignored on restore (crash consistency)

Elastic resume: leaves are stored UNSHARDED per leaf (each host writes its
addressable slice; on single-host CPU that's the whole array).  `restore`
re-shards onto whatever mesh the new job brings up — a job restarted on a
different device count resumes cleanly (tested in tests/test_checkpoint.py).

Async save: `save(..., blocking=False)` snapshots to host memory and writes
in a background thread so the train loop isn't stalled by I/O (the usual
fleet trick to keep goodput during frequent checkpoints).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading

import numpy as np

import jax
import ml_dtypes

COMMITTED = "_COMMITTED"

# dtypes numpy's npz container can't serialize natively: store as raw uint
# bits + a dtype entry in the manifest.
_RAW_DTYPES = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _flatten_with_names(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in leaves}


def _encode(arr: np.ndarray) -> tuple[np.ndarray, str]:
    name = arr.dtype.name
    if name in _RAW_DTYPES:
        return arr.view(_RAW_DTYPES[name][1]), name
    return arr, name


def _decode(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _RAW_DTYPES:
        return arr.view(_RAW_DTYPES[dtype_name][0])
    return arr


class CheckpointManager:
    def __init__(self, directory: str, host_id: int = 0, keep: int = 3):
        self.dir = directory
        self.host_id = host_id
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, *, blocking: bool = True) -> str:
        """Write a checkpoint for `step`.  Atomic: commit marker last."""
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        if blocking:
            return self._write(step, host_tree)
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree), daemon=True)
        self._thread.start()
        return os.path.join(self.dir, f"step_{step}")

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree) -> str:
        final = os.path.join(self.dir, f"step_{step}")
        tmp = tempfile.mkdtemp(prefix=f".step_{step}_", dir=self.dir)
        try:
            named = _flatten_with_names(host_tree)
            encoded, dtypes = {}, {}
            for k, v in named.items():
                encoded[k], dtypes[k] = _encode(v)
            shard_path = os.path.join(tmp, f"shard_{self.host_id}.npz")
            np.savez(shard_path, **encoded)
            digest = hashlib.sha256(open(shard_path, "rb").read()).hexdigest()
            treedef = jax.tree.structure(host_tree)
            manifest = {
                "step": step,
                "host_id": self.host_id,
                "leaf_names": sorted(named),
                "dtypes": dtypes,
                "shard_sha256": {str(self.host_id): digest},
                "treedef": str(treedef),
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f, indent=1)
            with open(os.path.join(tmp, COMMITTED), "w") as f:
                f.write("ok")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return final

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and os.path.exists(
                os.path.join(self.dir, d, COMMITTED)
            ):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, example_tree, step: int | None = None, *, shardings=None):
        """Restore into the structure of `example_tree`; device placement per
        `shardings` (a matching tree of NamedSharding) for elastic resume."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        if not os.path.exists(os.path.join(path, COMMITTED)):
            raise FileNotFoundError(f"checkpoint {path} not committed")
        manifest = json.load(open(os.path.join(path, "manifest.json")))
        shard_file = os.path.join(path, f"shard_{self.host_id}.npz")
        digest = hashlib.sha256(open(shard_file, "rb").read()).hexdigest()
        if manifest["shard_sha256"][str(self.host_id)] != digest:
            raise IOError(f"checkpoint {path} corrupt (hash mismatch)")
        data = np.load(shard_file)

        flat = jax.tree_util.tree_flatten_with_path(example_tree)
        leaves, paths = [], []
        for p, ex in flat[0]:
            name = jax.tree_util.keystr(p)
            arr = _decode(data[name], manifest.get("dtypes", {}).get(name, ""))
            if tuple(arr.shape) != tuple(ex.shape):
                raise ValueError(f"{name}: shape {arr.shape} != {ex.shape}")
            leaves.append(arr.astype(ex.dtype))
            paths.append(p)
        tree = jax.tree.unflatten(flat[1], leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree, step
