"""Distributed policy package: sharding rules for the launch-time steps.

`repro.dist.sharding` maps param/cache pytrees to PartitionSpecs under a
small rule object (`ShardRules`).  The planned fault-tolerance module
(`repro.dist.fault`) is still unbuilt — `repro.launch.train` falls back to
its inline StepWatchdog when the import fails.
"""
