"""Sharding policy: pytree -> PartitionSpec trees for the jitted steps.

One rule object (`ShardRules`) drives every placement decision the launcher
makes, so train / prefill / decode steps and the GPP weight streamer all
agree on where a tensor lives:

  tp_axis     tensor parallelism: the output-feature dim of column-parallel
              projections (q/k/v/up/gate, embeddings' vocab dim) and the
              contraction dim of the matching row-parallel ones (o-proj,
              down-proj) — GSPMD inserts the reduce.  MoE expert stacks put
              the EXPERT dim here under `moe_ep_mode="tp"`.
  fsdp_axes   ZeRO-3: one additional dim of every large tensor is sharded
              over the data axes and all-gathered per layer — exactly the
              "off-chip weight rewrite" the paper's streamer schedules; the
              streaming specs below are the (sharded, gathered) pair
              `core.streamer.stream_layers` constrains between.
  dp_axes     batch sharding for activations/caches.

Placement is shape-driven (dims must divide the axis size; anything that
doesn't stays replicated), so smoke configs on a 2x2 host mesh and the
production 16x16 mesh go through the same code path.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import PartitionSpec as P

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ShardRules:
    tp_axis: str = "model"
    fsdp_axes: "tuple[str, ...]" = ("data",)
    dp_axes: "tuple[str, ...]" = ("data",)
    fsdp: bool = True                  # ZeRO-3 shard params over fsdp_axes
    moe_ep_mode: str = "tp"            # experts over tp_axis ("tp") or dp
    moe_serve_resident: bool = False   # serving: experts resident, no FSDP


# row-parallel weights: TP goes on the leading (contraction) dim so the
# matmul reduces over the already-sharded axis (o-proj, down-proj)
_ROW_PARALLEL = ("w_o", "w_down", "w_out")
# 1-D / tiny leaves that always stay replicated
_REPLICATED = ("scale", "kv_norm", "q_norm", "k_norm", "b_q", "b_k", "b_v")


def _axis_size(mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape.get(a, 1)
    return n


def _axes_entry(axes: "tuple[str, ...]"):
    """PartitionSpec entry for a 1-or-many axis tuple."""
    return axes if len(axes) > 1 else axes[0]


def _leaf_name(path) -> str:
    for k in reversed(path):
        key = getattr(k, "key", None)
        if isinstance(key, str):
            return key
    return ""


def _is_stacked(path) -> bool:
    """Leaves under the "blocks" group carry a leading superblock-stack dim
    (same convention as transformer.is_stacked_cache_path)."""
    return any(getattr(k, "key", None) == "blocks" for k in path)


def _leaf_pspec(shape, lead: int, name: str, mesh, rules: ShardRules,
                *, fsdp: "bool | None" = None) -> P:
    """Placement for one leaf: TP dim first, then one FSDP dim, shape-gated."""
    fsdp = rules.fsdp if fsdp is None else fsdp
    dims: "list[Any]" = [None] * len(shape)
    rank = len(shape) - lead
    if rank <= 1 or name in _REPLICATED:
        return P(*dims)
    tp = mesh.shape.get(rules.tp_axis, 1)
    tp_dim = None
    if tp > 1:
        is_expert = rank == 3 and name in ("w_gate", "w_up", "w_down")
        if is_expert and rules.moe_ep_mode == "tp":
            cand = lead                       # expert dim over the model axis
        elif name in _ROW_PARALLEL or name == "embedding":
            cand = lead                       # contraction / vocab dim
        else:
            cand = len(shape) - 1             # column-parallel output dim
        for d in (cand, len(shape) - 1):
            if shape[d] % tp == 0:
                tp_dim = d
                dims[d] = rules.tp_axis
                break
    if fsdp and _axis_size(mesh, rules.fsdp_axes) > 1:
        fs = _axis_size(mesh, rules.fsdp_axes)
        for d in range(lead, len(shape)):
            if d != tp_dim and shape[d] % fs == 0:
                dims[d] = _axes_entry(rules.fsdp_axes)
                break
    return P(*dims)


def param_pspecs(pspecs: Pytree, mesh, rules: ShardRules) -> Pytree:
    """PartitionSpec tree for the full param pytree (stacked "blocks" leaves
    keep their leading superblock dim unsharded — it is the scan axis)."""
    def f(path, s):
        return _leaf_pspec(s.shape, 1 if _is_stacked(path) else 0,
                           _leaf_name(path), mesh, rules)

    return jax.tree_util.tree_map_with_path(f, pspecs)


# ---------------------------------------------------------------------------
# streaming (sharded -> gathered) spec pairs for core.streamer.stream_layers
# ---------------------------------------------------------------------------

def sharded_pspecs_one_layer(tree: Pytree, mesh, rules: ShardRules) -> Pytree:
    """Per-layer resident layout: TP + the ZeRO-3 FSDP shard — the "off-chip"
    form the streamer gathers FROM."""
    def f(path, s):
        return _leaf_pspec(s.shape, 0, _leaf_name(path), mesh, rules)

    return jax.tree_util.tree_map_with_path(f, tree)


def gathered_pspecs(tree: Pytree, mesh, rules: ShardRules) -> Pytree:
    """Gathered (compute) layout: the FSDP dim replicated again, TP kept —
    what one layer looks like while its GeMMs run."""
    def f(path, s):
        return _leaf_pspec(s.shape, 0, _leaf_name(path), mesh, rules,
                           fsdp=False)

    return jax.tree_util.tree_map_with_path(f, tree)


# ---------------------------------------------------------------------------
# cache placement
# ---------------------------------------------------------------------------

def cache_pspecs(tree: Pytree, mesh, rules: ShardRules, batch: int) -> Pytree:
    """KV-cache / recurrent-state placement: batch over the dp axes when it
    divides them; otherwise (long-context B < dp, e.g. long_500k at B=1) the
    SEQUENCE dim is sharded over dp instead, so a 500k-token cache never
    has to fit one device."""
    dpn = _axis_size(mesh, rules.dp_axes)
    dp_entry = _axes_entry(rules.dp_axes)

    def f(path, s):
        lead = 1 if _is_stacked(path) else 0
        dims: "list[Any]" = [None] * len(s.shape)
        if dpn > 1:
            if batch % dpn == 0:
                dims[lead] = dp_entry
            elif (len(s.shape) > lead + 1
                  and s.shape[lead + 1] % dpn == 0):
                dims[lead + 1] = dp_entry
        return P(*dims)

    return jax.tree_util.tree_map_with_path(f, tree)
