"""zamba2-2.7b [hybrid] — Mamba2 + shared attn blocks [arXiv:2411.15242; hf].

Assigned: 54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000,
ssm_state=64.  Pattern: 5 Mamba2 blocks then the SHARED attention+MLP block
(one param set reused by all 9 superblocks — the paper's weight-reuse limit
case: stream once, reuse).  SSM state is O(1) -> runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    d_model=2560,
    num_layers=54,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    pattern=("mamba", "mamba", "mamba", "mamba", "mamba", "shared_attn"),
    ssm_state_dim=64,
    ssm_expansion=2,
    rope_theta=1e4,
    subquadratic=True,
)

SMOKE = CONFIG.with_(
    d_model=64, num_layers=12, num_heads=4, num_kv_heads=4, d_ff=128,
    vocab_size=512, ssm_state_dim=16,
)
