"""gemma3-12b [dense] — 5:1 local:global, 128k ctx [hf:google/gemma-3; unverified].

Assigned: 48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.
Pattern: 5 sliding-window (1024) layers per 1 global layer; tied embeddings
with sqrt(d) scaling.  Local layers bound the cache and only 8 global layers
carry full-length KV, so long_500k at B=1 is feasible -> runs long_500k.
(Single rope_theta is used for both local and global layers — simplification
noted in DESIGN.md.)
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    d_model=3840,
    num_layers=48,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    pattern=("dense:window",) * 5 + ("dense",),
    window_size=1024,
    tie_embeddings=True,
    embed_scale=True,
    act="swiglu",
    subquadratic=True,
)

SMOKE = CONFIG.with_(
    d_model=64, num_layers=12, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, window_size=16,
)
