"""h2o-danube-1.8b [dense] — llama+mistral mix, SWA [arXiv:2401.16818; hf].

Assigned: 24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000.  Sliding
window 4096 bounds the decode cache -> runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    d_model=2560,
    num_layers=24,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    pattern=("dense:window",),
    window_size=4096,
    rope_theta=1e4,
    subquadratic=True,
)

SMOKE = CONFIG.with_(
    d_model=64, num_layers=2, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=512, window_size=16,
)
