from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, cells_for

__all__ = ["SHAPES", "ModelConfig", "ShapeConfig", "cells_for"]
