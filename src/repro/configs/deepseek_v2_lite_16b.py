"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, 2 shared + 64 routed top-6
[arXiv:2405.04434; hf].

Assigned: 27L d_model=2048 16H d_ff=1408 (per-expert) vocab=102400, MoE 64e
top-6.  Layer 0 dense with d_ff=10944 (published); MLA latent cache
(kv_lora=512 + rope 64) is the decode-memory win.  V2-Lite has no q
compression (q_lora_rank=None).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    d_model=2048,
    num_layers=27,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=10944,
    vocab_size=102400,
    pattern=("moe",),
    prefix_pattern=("dense",),
    kv_lora_rank=512,
    q_lora_rank=None,
    rope_head_dim=64,
    num_experts=64,
    experts_per_token=6,
    num_shared_experts=2,
    moe_d_ff=1408,
    rope_theta=1e4,
)

SMOKE = CONFIG.with_(
    d_model=64, num_layers=3, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, kv_lora_rank=32, rope_head_dim=8,
    num_experts=8, experts_per_token=2, moe_d_ff=32,
)
