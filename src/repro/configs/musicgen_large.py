"""musicgen-large [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284; hf].

Assigned: 48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048.  The EnCodec
frontend is a STUB per the assignment: input_specs() provides precomputed
frame embeddings (B, S, D); the backbone predicts codebook tokens (vocab
2048).  GELU FFN per the published config.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    d_model=2048,
    num_layers=48,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    pattern=("dense",),
    input_mode="embeddings",
    act="gelu",
    rope_theta=1e4,
)

SMOKE = CONFIG.with_(
    d_model=64, num_layers=2, num_heads=4, num_kv_heads=4, d_ff=128,
    vocab_size=64,
)
