"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

Assigned: 48L d_model=2048 4H (GQA kv=4) d_ff=0 vocab=50304.  d_ff=0 means
mixer-only blocks (the xLSTM block's projections live inside the mixer).
Sub-quadratic (matrix-memory recurrence) -> runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    d_model=2048,
    num_layers=48,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    pattern=("mlstm", "slstm"),
    subquadratic=True,
)

SMOKE = CONFIG.with_(
    d_model=64, num_layers=4, num_heads=2, num_kv_heads=2, vocab_size=512,
)
