"""llama-3.2-vision-11b [vlm] — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].

Assigned: 40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.  Every
5th layer cross-attends to 1600 patch embeddings supplied by the stubbed
vision tower (input_specs()); the other 32 are standard GQA self-attention.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    d_model=4096,
    num_layers=40,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    pattern=("dense", "dense", "dense", "dense", "cross"),
    encoder_tokens=1600,
    rope_theta=5e5,
)

SMOKE = CONFIG.with_(
    d_model=64, num_layers=10, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, encoder_tokens=8,
)
