"""qwen2-7b [dense] — GQA, QKV bias [arXiv:2407.10671; hf].

Assigned: 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    d_model=3584,
    num_layers=28,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    pattern=("dense",),
    qkv_bias=True,
)

SMOKE = CONFIG.with_(
    d_model=64, num_layers=2, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
)
