"""kimi-k2-1t-a32b [moe] — trillion-param MoE [arXiv:2501.kimi2; unverified].

Assigned: 61L d_model=7168 64H (GQA kv=8) d_ff=2048 (per-expert) vocab=163840,
MoE 384e top-8.  Following DeepSeek lineage, layer 0 is dense (d_ff=18432,
the published K2 dense-layer width); assigned d_ff=2048 is the routed-expert
hidden.  The assignment pins GQA kv=8 (real K2 uses MLA — noted in DESIGN.md
§Arch-applicability); head_dim=128 per the public config.

This is the paper's flagship workload: 1T total / 32B active params — the
expert weights *cannot* be resident and must stream — the exact
concurrent write/compute regime of the paper.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    d_model=7168,
    num_layers=61,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=18432,
    vocab_size=163840,
    pattern=("moe",),
    prefix_pattern=("dense",),
    num_experts=384,
    experts_per_token=8,
    num_shared_experts=1,
    moe_d_ff=2048,
    optimizer="adafactor",  # AdamW f32 moments (8 TB) cannot fit a 4 TB pod
)

SMOKE = CONFIG.with_(
    d_model=64, num_layers=3, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, num_experts=8, experts_per_token=2,
    moe_d_ff=32,
)
