"""Model/arch configuration dataclasses and the assigned input shapes."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.streamer import StreamSettings


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|ssm|hybrid|audio|vlm
    d_model: int
    num_layers: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    # layer composition
    pattern: tuple[str, ...] = ("dense",)
    prefix_pattern: tuple[str, ...] = ()
    head_dim: int | None = None
    # attention
    qkv_bias: bool = False
    rope_theta: float = 1e6
    window_size: int | None = None
    # MLA
    kv_lora_rank: int | None = None
    q_lora_rank: int | None = None
    rope_head_dim: int = 64
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int | None = None
    moe_capacity_factor: float = 1.25
    moe_serve_resident: bool = False # serving: experts resident, E over model
                                     # x d_ff over data (set by the serve steps)
    moe_ep_mode: str = "tp"          # tp: experts over model axis (+FSDP);
                                     # dp: experts over data x d_ff over model
                                     #     (weights fully sharded resident,
                                     #     tokens all-to-all — no FSDP gathers)
    # SSM
    ssm_state_dim: int = 0
    ssm_expansion: int = 2
    # modality
    input_mode: str = "tokens"       # tokens | embeddings (musicgen frontend stub)
    encoder_tokens: int = 0          # vlm: # patch embeddings from the stub
    # misc
    act: str = "swiglu"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma-style sqrt(d) embedding scaling
    dtype: str = "bfloat16"
    subquadratic: bool = False       # eligible for long_500k decode
    stream: StreamSettings = StreamSettings()
    dense_kernel: str = "auto"       # matmul routing (kernels.ops.dense /
                                     # dense_grouped) for EVERY projection —
                                     # MLP, attention q/k/v/o, MLA up/down,
                                     # MoE router+experts, SSM/xLSTM in/out:
                                     # auto | ref | kernel | interpret — auto
                                     # streams big weights through the GPP
                                     # Pallas kernel on TPU, jnp elsewhere
    paged_attn_kernel: str = "auto"  # paged serving READ path routing
                                     # (kernels.ops.paged_attn, used by the
                                     # *_paged attention fns): auto | pallas |
                                     # interpret | ref — "pallas" streams KV
                                     # blocks through the VMEM-ring Pallas
                                     # kernel (block tables as scalar
                                     # prefetch), "ref" gathers pools through
                                     # the tables (pre-kernel math, exact),
                                     # "auto" = pallas on TPU / ref elsewhere
    remat: str = "block"             # none | block  (activation checkpointing)
    optimizer: str = "adamw"         # adamw | adafactor (1T-scale state budget)
    # serving (paged-KV engine defaults; ServeConfig fields of the same
    # concept override per deployment)
    serve_block_size: int = 16       # tokens per paged-KV block
    serve_token_budget: int = 0      # flat per-step token target for the
                                     # chunked-prefill scheduler; 0 = auto
                                     # (slots + 2 blocks — one chunk of
                                     # prefill riding along with full decode)
    prefix_cache: bool = False       # paged serving: radix-tree shared-
                                     # prefix KV reuse (serving/prefix.py) —
                                     # admission maps previously computed
                                     # prompt-prefix blocks into the lane's
                                     # tables and prefill skips the matched
                                     # chunks; cached blocks are refcounted,
                                     # appended into via copy-on-write
                                     # fork_block, and LRU-evicted under
                                     # block pressure before any preemption.
                                     # ServeConfig.prefix_cache overrides.
    prefix_cache_blocks: int = 0     # cap on block references the prefix
                                     # index may pin per deployment (0 =
                                     # unbounded; pressure-driven eviction
                                     # applies either way).
                                     # ServeConfig.prefix_cache_blocks
                                     # overrides.
    speculation: bool = False        # paged serving: speculative decoding —
                                     # self-drafted (prompt-lookup n-gram)
                                     # or draft-model tokens are scored in
                                     # ONE batched verify pass of draft_len+1
                                     # tokens per lane, amortizing the
                                     # streamed weight working set over up
                                     # to draft_len+1 tokens instead of 1
                                     # (the GPP bytes-per-useful-token fix
                                     # for decode).  Greedy/temperature
                                     # output streams are token-for-token
                                     # identical with speculation on or off;
                                     # rejected drafts roll back via block-
                                     # table truncation.
                                     # ServeConfig.speculation overrides.
    draft_len: int = 4               # max draft tokens per lane per verify
                                     # step (k; the verify shape is
                                     # (slots, k+1)).  Actual per-step
                                     # drafts also respect the scheduler's
                                     # flatness slack
                                     # (core.schedule.plan_verify_budget)
                                     # and each lane's remaining quota.
                                     # ServeConfig.draft_len overrides.

    # ---- observability (repro.obs; near-zero overhead when off) ----
    obs: bool = False                # serving telemetry: request/kernel
                                     # trace spans (Perfetto trace-event
                                     # JSON via --trace-out), TTFT/TPOT
                                     # histograms (JSONL via --metrics-out),
                                     # and step wall times in the ledger.
                                     # Off: every instrumentation site is a
                                     # no-op (regression-gated < 5%
                                     # tokens/sec overhead when ON in
                                     # BENCH_serving.json).
                                     # ServeConfig.obs overrides.
    obs_trace_capacity: int = 65536  # trace ring-buffer capacity (events);
                                     # once full the OLDEST events drop and
                                     # the export's otherData.dropped_events
                                     # counts them.
                                     # ServeConfig.trace_capacity overrides.
    metrics_retention: int = 0       # per-step ledger rows kept in memory
                                     # (0 = unbounded, the test/bench
                                     # default).  > 0: a ring of the most
                                     # recent N rows; evicted rows fold into
                                     # BandwidthLedger.rollup so lifetime
                                     # totals stay exact while long serving
                                     # runs stop growing per step.
                                     # ServeConfig.metrics_retention
                                     # overrides.

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def num_superblocks(self) -> int:
        body = self.num_layers - len(self.prefix_pattern)
        if body % len(self.pattern):
            raise ValueError(
                f"{self.name}: {body} body layers not divisible by pattern "
                f"{self.pattern}"
            )
        return body // len(self.pattern)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- analytic parameter counts (roofline MODEL_FLOPS) ----
    def _block_params(self, kind: str) -> int:
        d, f, hd = self.d_model, self.d_ff, self.resolved_head_dim
        H, KV = self.num_heads, self.num_kv_heads
        base = kind.split(":")[0]
        n_mlp = d * f * (3 if self.act == "swiglu" else 2)
        if base in ("dense", "shared_attn", "moe"):
            if self.kv_lora_rank:
                r, rr = self.kv_lora_rank, self.rope_head_dim
                a = d * (r + rr) + r * H * hd * 2 + H * hd * d
                if self.q_lora_rank:
                    a += d * self.q_lora_rank + self.q_lora_rank * H * (hd + rr)
                else:
                    a += d * H * (hd + rr)
            else:
                a = d * H * hd + 2 * d * KV * hd + H * hd * d
            if base == "moe":
                fm = self.moe_d_ff or f
                active = self.experts_per_token * d * fm * (3 if self.act == "swiglu" else 2)
                shared = (self.num_shared_experts and
                          d * fm * self.num_shared_experts *
                          (3 if self.act == "swiglu" else 2)) or 0
                router = d * self.num_experts
                return a + active + shared + router
            return a + n_mlp
        if base == "mamba":
            di, N = self.ssm_expansion * d, self.ssm_state_dim
            return d * 2 * di + d * 2 * N + d * H + di * d
        if base in ("mlstm", "slstm"):
            if base == "mlstm":
                mix = 3 * d * H * (d // H) + 2 * d * H + H * (d // H) * d + d * d
            else:
                mix = 3 * d * d + 2 * d * H
            return mix + n_mlp
        if base == "cross":
            return d * H * hd + 2 * d * KV * hd + H * hd * d + n_mlp
        raise ValueError(kind)

    def active_params(self) -> int:
        """Active (per-token) parameter count — MoE counts top-k experts."""
        n = 0
        for k in self.prefix_pattern:
            n += self._block_params(k)
        for k in self.pattern:
            n += self._block_params(k) * self.num_superblocks if not k.startswith(
                "shared_attn") else 0
        if any(k.startswith("shared_attn") for k in self.pattern):
            n += self._block_params("shared_attn")
        if self.input_mode == "tokens":
            n += self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        return n

    def total_params(self) -> int:
        """Total parameter count (MoE counts all experts)."""
        if not self.num_experts:
            return self.active_params()
        fm = self.moe_d_ff or self.d_ff
        per_layer_all = self.num_experts * self.d_model * fm * (
            3 if self.act == "swiglu" else 2)
        per_layer_active = self.experts_per_token * self.d_model * fm * (
            3 if self.act == "swiglu" else 2)
        n_moe_layers = sum(1 for k in self.pattern if k.startswith("moe")) \
            * self.num_superblocks + sum(
                1 for k in self.prefix_pattern if k.startswith("moe"))
        return self.active_params() + n_moe_layers * (per_layer_all - per_layer_active)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cells_for(cfg: ModelConfig) -> list[str]:
    """The assigned (arch x shape) cells: long_500k only for sub-quadratic."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        names.append("long_500k")
    return names
