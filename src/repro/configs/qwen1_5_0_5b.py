"""qwen1.5-0.5b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B; hf].

Assigned: 24L d_model=1024 16H (GQA kv=16) d_ff=2816 vocab=151936.
Tied embeddings (published 0.5B config).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    d_model=1024,
    num_layers=24,
    num_heads=16,
    num_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    pattern=("dense",),
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1e4,
)

SMOKE = CONFIG.with_(
    d_model=64, num_layers=2, num_heads=4, num_kv_heads=4, d_ff=128,
    vocab_size=512,
)
