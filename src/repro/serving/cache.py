"""Paged KV cache: fixed-size blocks + per-lane block tables over shared pools.

The serving analogue of the paper's macro pool: physical KV blocks are the
"macros", a lane's block table is its schedule slot assignment, and capacity
is `num_blocks * block_size` tokens shared across every lane — not
`slots * max_len` reserved per lane as in the dense seed cache.  A lane
holding a 6-token prompt pins one 16-token block, not a whole `max_len` row.

Layout contract (consumed by `models.attention` paged read/write and
`models.transformer.prefill_chunk` / `decode_step_paged`):

  * physical block 0 is RESERVED as a null/scratch block: unmapped table
    entries read it (masked out by the causal mask) and inactive decode
    lanes write into it, so the jitted step functions never branch on
    occupancy.
  * a lane's logical block `b` holds absolute positions
    `[b*block_size, (b+1)*block_size)`; table entry `tables[lane, b]` is the
    physical block id (0 while unmapped).

This module is pure host-side bookkeeping (numpy tables + a free list); the
device-side pools live in the engine's cache pytree and are permuted by the
engine when `defragment` hands back a physical-block permutation.
"""
from __future__ import annotations

import dataclasses

import numpy as np


class BlockAllocator:
    """Free-list allocator over physical blocks 1..num_blocks-1 (0 reserved).

    Allocation is all-or-nothing: a request for `n` blocks either returns
    `n` ids or None, so callers can fall back to preemption atomically.
    """

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the reserved null block)")
        self.num_blocks = num_blocks
        # LIFO free list: recently-freed blocks are re-used first (warm)
        self._free: list[int] = list(range(num_blocks - 1, 0, -1))

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def capacity(self) -> int:
        """Allocatable blocks (excludes the reserved null block)."""
        return self.num_blocks - 1

    def allocate(self, n: int) -> "list[int] | None":
        if n < 0:
            raise ValueError("n >= 0")
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        return out

    def free(self, blocks: "list[int]") -> None:
        for b in blocks:
            if not 1 <= b < self.num_blocks:
                raise ValueError(f"bad block id {b}")
            if b in self._free:
                raise ValueError(f"double free of block {b}")
        self._free.extend(blocks)

    def reset_free(self, free: "list[int]") -> None:
        """Replace the free list (defragment rebuilds it compactly)."""
        self._free = list(free)


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    num_blocks: int          # physical blocks INCLUDING reserved block 0
    block_size: int          # tokens per block
    max_blocks_per_seq: int  # block-table width: max_len // block_size

    @property
    def max_len(self) -> int:
        return self.max_blocks_per_seq * self.block_size

    @property
    def token_capacity(self) -> int:
        """Tokens the pool can hold across all lanes (null block excluded)."""
        return (self.num_blocks - 1) * self.block_size


class PagedKVCache:
    """Block tables + allocator for `slots` lanes over one shared pool.

    Pools themselves (one per attention layer) live in the engine's cache
    pytree; this object owns which physical block backs which (lane,
    logical-block) coordinate.
    """

    def __init__(self, *, slots: int, num_blocks: int, block_size: int,
                 max_blocks_per_seq: int):
        self.cfg = PagedCacheConfig(num_blocks, block_size, max_blocks_per_seq)
        self.slots = slots
        self.allocator = BlockAllocator(num_blocks)
        self.tables = np.zeros((slots, max_blocks_per_seq), np.int32)
        self.num_mapped = np.zeros((slots,), np.int64)  # logical blocks mapped
        # logical blocks [0, released) were freed back after sliding-window
        # expiry (release_expired); their table entries read the null block
        self.released = np.zeros((slots,), np.int64)

    # ------------------------------------------------------------ queries
    @property
    def blocks_in_use(self) -> int:
        return self.allocator.capacity - self.allocator.num_free

    @property
    def num_free(self) -> int:
        return self.allocator.num_free

    def blocks_for(self, lane: int) -> "list[int]":
        """Physical blocks the lane still holds (released entries excluded)."""
        return [int(b) for b in self.tables[lane, : self.num_mapped[lane]] if b]

    def blocks_needed(self, lane: int, upto_pos: int) -> int:
        """Additional blocks lane needs so position `upto_pos` is backed."""
        want = upto_pos // self.cfg.block_size + 1
        return max(0, want - int(self.num_mapped[lane]))

    # --------------------------------------------------------- mutations
    def ensure(self, lane: int, upto_pos: int) -> bool:
        """Map blocks so `upto_pos` is writable.  False if the pool is out
        of free blocks (caller decides whether to preempt)."""
        need = self.blocks_needed(lane, upto_pos)
        if need == 0:
            return True
        have = int(self.num_mapped[lane])
        if have + need > self.cfg.max_blocks_per_seq:
            raise ValueError(
                f"lane {lane}: position {upto_pos} exceeds the "
                f"{self.cfg.max_len}-token block table")
        blocks = self.allocator.allocate(need)
        if blocks is None:
            return False
        self.tables[lane, have : have + need] = blocks
        self.num_mapped[lane] = have + need
        return True

    def free_lane(self, lane: int) -> None:
        n = int(self.num_mapped[lane])
        if n:
            # skip entries already zeroed by release_expired
            live = [int(b) for b in self.tables[lane, :n] if b]
            if live:
                self.allocator.free(live)
        self.tables[lane, :] = 0
        self.num_mapped[lane] = 0
        self.released[lane] = 0

    def release_expired(self, lane: int, pos: int, horizon: int) -> int:
        """Free the lane's blocks that fell wholly behind the sliding-window
        horizon: with the next query at position `pos`, the oldest visible
        position is pos - horizon + 1, so logical block b is dead once
        (b+1)*block_size <= pos - horizon + 1 — for this query and every
        later one (positions only grow).  Table entries are zeroed (reads
        land on the null block, already hidden by the window mask) and the
        physical blocks go back to the allocator, so blocks_in_use plateaus
        at ~horizon/block_size per lane instead of growing with context.

        Only valid when EVERY layer's mask has expired the blocks — the
        caller (engine) gates on `transformer.window_horizon`.  Returns the
        number of blocks freed.
        """
        if horizon < 1:
            raise ValueError("horizon >= 1")
        bs = self.cfg.block_size
        expire_end = min(max(0, pos - horizon + 1) // bs,
                         int(self.num_mapped[lane]))
        start = int(self.released[lane])
        if expire_end <= start:
            return 0
        blocks = [int(b) for b in self.tables[lane, start:expire_end] if b]
        if blocks:
            self.allocator.free(blocks)
        self.tables[lane, start:expire_end] = 0
        self.released[lane] = expire_end
        return len(blocks)

    def defragment(self) -> np.ndarray:
        """Compact live blocks to the low end of the pool.

        Returns `perm` (shape (num_blocks,), int32) with
        `new_pool[i] = old_pool[perm[i]]` — the engine applies it to every
        device pool; tables and the free list are rewritten here so the
        compacted ids are contiguous (gathers touch one dense pool prefix,
        the locality the GPP streaming schedule wants).
        """
        nb = self.cfg.num_blocks
        live: list[int] = [0]                        # null block stays put
        for lane in range(self.slots):
            live.extend(self.blocks_for(lane))       # skips released (0) slots
        live_set = set(live)
        dead = [b for b in range(nb) if b not in live_set]
        perm = np.asarray(live + dead, np.int32)
        assert perm.shape == (nb,)
        old_to_new = np.empty(nb, np.int64)
        old_to_new[perm] = np.arange(nb)
        for lane in range(self.slots):
            n = int(self.num_mapped[lane])
            if n:
                self.tables[lane, :n] = old_to_new[self.tables[lane, :n]]
        self.allocator.reset_free(list(range(nb - 1, len(live) - 1, -1)))
        return perm
