"""Paged KV cache: fixed-size blocks + per-lane block tables over shared pools.

The serving analogue of the paper's macro pool: physical KV blocks are the
"macros", a lane's block table is its schedule slot assignment, and capacity
is `num_blocks * block_size` tokens shared across every lane — not
`slots * max_len` reserved per lane as in the dense seed cache.  A lane
holding a 6-token prompt pins one 16-token block, not a whole `max_len` row.

Since the prefix-cache PR, physical blocks are REFCOUNTED: a block may be
mapped by several lanes at once (shared prompt prefix) and/or held by the
radix prefix index (`serving.prefix.PrefixCache`).  The sharing contract:

  * every mapping source holds one reference — each lane table entry is one
    ref, and the prefix index holds at most one ref per block
    (`index_acquire`/`index_release`, tracked separately in `index_ref` so
    write-aliasing checks can distinguish "shared with the index" — safe to
    append past the index's claimed tokens — from "shared with another
    lane" — never writable);
  * a block returns to the allocator only when its last reference drops;
  * a lane may only WRITE a block it holds exclusively (modulo the index);
    appending into a partially-filled shared block goes through `fork_block`
    (copy-on-write: remap the table entry to a fresh block, the engine
    copies the pool rows).

Layout contract (consumed by `models.attention` paged read/write and
`models.transformer.prefill_chunk` / `decode_step_paged`):

  * physical block 0 is RESERVED as a null/scratch block: unmapped table
    entries read it (masked out by the causal mask) and inactive decode
    lanes write into it, so the jitted step functions never branch on
    occupancy.
  * a lane's logical block `b` holds absolute positions
    `[b*block_size, (b+1)*block_size)`; table entry `tables[lane, b]` is the
    physical block id (0 while unmapped, released, or shared-as-null).

`GroupedPagedCache` stacks one `PagedKVCache` per LAYER GROUP — layers
bucketed by attention reach (`models.transformer.layer_group_keys`): global
layers in one group, sliding-window layers in another.  Each group has its
own block-id space, tables, and allocator, so `release_expired` frees a
windowed group's blocks even while a global group in the same model pins
full history (the gemma3 limitation the shared-table design had).

This module is pure host-side bookkeeping (numpy tables + free lists); the
device-side pools live in the engine's cache pytree, are permuted by the
engine when `defragment` hands back a physical-block permutation, and
receive copy-on-write block copies via the `pending_copies` queue the
engine drains each step before any model call.
"""
from __future__ import annotations

import dataclasses

import numpy as np


class BlockAllocator:
    """Free-list allocator over physical blocks 1..num_blocks-1 (0 reserved).

    Allocation is all-or-nothing: a request for `n` blocks either returns
    `n` ids or None, so callers can fall back to preemption atomically.
    """

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the reserved null block)")
        self.num_blocks = num_blocks
        # LIFO free list: recently-freed blocks are re-used first (warm)
        self._free: list[int] = list(range(num_blocks - 1, 0, -1))

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def capacity(self) -> int:
        """Allocatable blocks (excludes the reserved null block)."""
        return self.num_blocks - 1

    def allocate(self, n: int) -> "list[int] | None":
        if n < 0:
            raise ValueError("n >= 0")
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        return out

    def free(self, blocks: "list[int]") -> None:
        for b in blocks:
            if not 1 <= b < self.num_blocks:
                raise ValueError(f"bad block id {b}")
            if b in self._free:
                raise ValueError(f"double free of block {b}")
        self._free.extend(blocks)

    def reset_free(self, free: "list[int]") -> None:
        """Replace the free list (defragment rebuilds it compactly)."""
        self._free = list(free)


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    num_blocks: int          # physical blocks INCLUDING reserved block 0
    block_size: int          # tokens per block
    max_blocks_per_seq: int  # block-table width: max_len // block_size

    @property
    def max_len(self) -> int:
        return self.max_blocks_per_seq * self.block_size

    @property
    def token_capacity(self) -> int:
        """Tokens the pool can hold across all lanes (null block excluded)."""
        return (self.num_blocks - 1) * self.block_size


class PagedKVCache:
    """Refcounted block tables + allocator for `slots` lanes over one pool.

    Pools themselves (one per attention layer) live in the engine's cache
    pytree; this object owns which physical block backs which (lane,
    logical-block) coordinate and how many mappings each block has.
    """

    def __init__(self, *, slots: int, num_blocks: int, block_size: int,
                 max_blocks_per_seq: int):
        self.cfg = PagedCacheConfig(num_blocks, block_size, max_blocks_per_seq)
        self.slots = slots
        self.allocator = BlockAllocator(num_blocks)
        self.tables = np.zeros((slots, max_blocks_per_seq), np.int32)
        self.num_mapped = np.zeros((slots,), np.int64)  # logical blocks mapped
        # logical blocks [0, released) were freed back after sliding-window
        # expiry (release_expired); their table entries read the null block
        self.released = np.zeros((slots,), np.int64)
        # reference counts: lane table entries + the prefix index each hold
        # one ref; a block is allocator-free iff ref_count == 0.  index_ref
        # flags the (at most one) prefix-index reference separately.
        self.ref_count = np.zeros((num_blocks,), np.int64)
        self.index_ref = np.zeros((num_blocks,), bool)

    # ------------------------------------------------------------ queries
    @property
    def blocks_in_use(self) -> int:
        return self.allocator.capacity - self.allocator.num_free

    @property
    def num_free(self) -> int:
        return self.allocator.num_free

    @property
    def blocks_shared(self) -> int:
        """Physical blocks currently mapped by more than one holder."""
        return int((self.ref_count >= 2).sum())

    def blocks_for(self, lane: int) -> "list[int]":
        """Physical blocks the lane still maps (released/null entries
        excluded; shared blocks included)."""
        return [int(b) for b in self.tables[lane, : self.num_mapped[lane]] if b]

    def table_snapshot(self, lane: int, nblocks: "int | None" = None) -> "list[int]":
        """The lane's first `nblocks` table entries INCLUDING zeros (released
        window entries / shared-null holes) — the prefix index adopts these
        verbatim so null coverage is visible to future matches."""
        n = int(self.num_mapped[lane]) if nblocks is None else nblocks
        if n > int(self.num_mapped[lane]):
            raise ValueError(
                f"lane {lane}: snapshot of {n} blocks but only "
                f"{int(self.num_mapped[lane])} mapped")
        return [int(b) for b in self.tables[lane, :n]]

    def blocks_needed(self, lane: int, upto_pos: int) -> int:
        """Additional blocks lane needs so position `upto_pos` is backed."""
        want = upto_pos // self.cfg.block_size + 1
        return max(0, want - int(self.num_mapped[lane]))

    # ------------------------------------------------------ ref accounting
    def _release(self, blocks: "list[int]") -> int:
        """Drop one (lane) reference per block; free those reaching zero.
        Returns the number of blocks returned to the allocator."""
        freed = []
        for b in blocks:
            if self.ref_count[b] <= 0:
                raise ValueError(f"release of unreferenced block {b}")
            self.ref_count[b] -= 1
            if self.ref_count[b] == 0:
                freed.append(b)
        if freed:
            self.allocator.free(freed)
        return len(freed)

    def index_acquire(self, block: int) -> None:
        """The prefix index adopts `block` (one ref; at most one per block)."""
        if not 1 <= block < self.cfg.num_blocks:
            raise ValueError(f"bad block id {block}")
        if self.index_ref[block]:
            raise ValueError(f"block {block} already index-held")
        if self.ref_count[block] <= 0:
            raise ValueError(f"index adoption of free block {block}")
        self.index_ref[block] = True
        self.ref_count[block] += 1

    def index_release(self, block: int) -> int:
        """Drop the prefix index's reference.  Returns 1 if the block went
        back to the allocator, else 0."""
        if not self.index_ref[block]:
            raise ValueError(f"block {block} not index-held")
        self.index_ref[block] = False
        return self._release([block])

    # --------------------------------------------------------- mutations
    def ensure(self, lane: int, upto_pos: int) -> bool:
        """Map blocks so `upto_pos` is writable.  False if the pool is out
        of free blocks (caller decides whether to evict/preempt)."""
        need = self.blocks_needed(lane, upto_pos)
        if need == 0:
            return True
        have = int(self.num_mapped[lane])
        if have + need > self.cfg.max_blocks_per_seq:
            raise ValueError(
                f"lane {lane}: position {upto_pos} exceeds the "
                f"{self.cfg.max_len}-token block table")
        blocks = self.allocator.allocate(need)
        if blocks is None:
            return False
        self.tables[lane, have : have + need] = blocks
        self.ref_count[blocks] = 1
        self.num_mapped[lane] = have + need
        return True

    def share_blocks(self, lane: int, blocks: "list[int]") -> None:
        """Map existing physical blocks (from the prefix index) into the
        lane's table, appending at the current high-water mark.  Zero
        entries map the null block (expired window coverage — reads are
        masked).  Each non-zero block gains one lane reference; the lane
        must treat shared blocks as READ-ONLY (append via `fork_block`)."""
        have = int(self.num_mapped[lane])
        if have + len(blocks) > self.cfg.max_blocks_per_seq:
            raise ValueError(
                f"lane {lane}: sharing {len(blocks)} blocks exceeds the "
                f"{self.cfg.max_blocks_per_seq}-entry table")
        for b in blocks:
            if b and self.ref_count[b] <= 0:
                raise ValueError(f"cannot share free block {b}")
        self.tables[lane, have : have + len(blocks)] = blocks
        for b in blocks:
            if b:
                self.ref_count[b] += 1
        self.num_mapped[lane] = have + len(blocks)

    def fork_block(self, lane: int, logical: int) -> "int | None":
        """Copy-on-write: make the lane's mapping of logical block `logical`
        exclusive so it can append into it.

        Returns the physical id now backing the entry: the ORIGINAL id when
        the lane already held it exclusively (no copy needed), a FRESH id
        when the block was shared (the caller must copy the pool rows old ->
        new before any write), or None when the pool has no free block for
        the copy (caller evicts/preempts and retries — already-forked
        entries are then exclusive, so the retry is idempotent)."""
        old = int(self.tables[lane, logical])
        if not old:
            raise ValueError(f"lane {lane}: logical block {logical} unmapped")
        if self.ref_count[old] == 1:
            # truly exclusive (a mapped entry's ref includes this lane, so
            # ref 1 implies no index claim either).  NOTE: an index-co-held
            # block (lane + index) is still COPIED — the index's tail claim
            # covers rows this fork may overwrite below the lane's append
            # point, so only a ref-1 block is handed back uncopied.
            return old
        got = self.allocator.allocate(1)
        if got is None:
            return None
        new = got[0]
        self.tables[lane, logical] = new
        self.ref_count[new] = 1
        self._release([old])
        return new

    def drop_last_shared(self, lane: int) -> None:
        """Undo the most recent single-block mapping (rollback of a failed
        multi-group tail fork at admission)."""
        have = int(self.num_mapped[lane])
        if have <= 0:
            raise ValueError(f"lane {lane}: nothing mapped")
        b = int(self.tables[lane, have - 1])
        if b:
            self._release([b])
        self.tables[lane, have - 1] = 0
        self.num_mapped[lane] = have - 1

    def free_lane(self, lane: int) -> None:
        n = int(self.num_mapped[lane])
        if n:
            # skip entries already zeroed by release_expired / null shares
            live = [int(b) for b in self.tables[lane, :n] if b]
            if live:
                self._release(live)
        self.tables[lane, :] = 0
        self.num_mapped[lane] = 0
        self.released[lane] = 0

    def release_expired(self, lane: int, pos: int, horizon: int) -> int:
        """Drop the lane's references on blocks that fell wholly behind the
        sliding-window horizon: with the next query at position `pos`, the
        oldest visible position is pos - horizon + 1, so logical block b is
        dead once (b+1)*block_size <= pos - horizon + 1 — for this query and
        every later one (positions only grow).  Table entries are zeroed
        (reads land on the null block, already hidden by the window mask);
        a block returns to the allocator only when no other lane and not the
        prefix index still holds it.  Returns the number of blocks freed.
        """
        if horizon < 1:
            raise ValueError("horizon >= 1")
        bs = self.cfg.block_size
        expire_end = min(max(0, pos - horizon + 1) // bs,
                         int(self.num_mapped[lane]))
        start = int(self.released[lane])
        if expire_end <= start:
            return 0
        blocks = [int(b) for b in self.tables[lane, start:expire_end] if b]
        freed = self._release(blocks) if blocks else 0
        self.tables[lane, start:expire_end] = 0
        self.released[lane] = expire_end
        return freed

    def truncate_blocks(self, lane: int, keep_blocks: int) -> int:
        """Roll back the lane's TAIL mappings so only the first
        `keep_blocks` logical blocks stay mapped (speculative-decode
        rollback: blocks ensured for rejected draft positions go straight
        back to the allocator).  Stale KV rows inside kept blocks need no
        scrubbing — the position-exact masks hide every row at or beyond
        the lane's next query position, and later writes overwrite them in
        place (the same argument that covers prefill-chunk pad rows).
        Returns the number of blocks freed."""
        if keep_blocks < 0:
            raise ValueError("keep_blocks >= 0")
        have = int(self.num_mapped[lane])
        if keep_blocks >= have:
            return 0
        blocks = [int(b) for b in self.tables[lane, keep_blocks:have] if b]
        freed = self._release(blocks) if blocks else 0
        self.tables[lane, keep_blocks:have] = 0
        self.num_mapped[lane] = keep_blocks
        return freed

    def assert_writable(self, lane: int, start_pos: int, end_pos: int) -> None:
        """No-write-aliasing guard: every mapped block covering token span
        [start_pos, end_pos) must be held by this lane alone (the prefix
        index's co-reference is allowed — it only claims tokens below the
        lane's write positions).  The paged-attention kernel and gather path
        only READ pools through tables; all writes funnel through the
        engine, which calls this before each prefill chunk / decode write.
        """
        bs = self.cfg.block_size
        for j in range(start_pos // bs, (end_pos - 1) // bs + 1):
            b = int(self.tables[lane, j])
            if b and self.ref_count[b] - int(self.index_ref[b]) != 1:
                raise AssertionError(
                    f"write aliasing: lane {lane} logical block {j} maps "
                    f"physical {b} with {int(self.ref_count[b])} refs "
                    f"(index_held={bool(self.index_ref[b])}) — shared "
                    "blocks are read-only; fork_block before appending")

    def defragment(self) -> np.ndarray:
        """Compact live blocks to the low end of the pool.

        Returns `perm` (shape (num_blocks,), int32) with
        `new_pool[i] = old_pool[perm[i]]` — the engine applies it to every
        device pool; tables, refcounts, and the free list are rewritten here
        so the compacted ids are contiguous (gathers touch one dense pool
        prefix, the locality the GPP streaming schedule wants).

        Shared blocks appear in MULTIPLE tables (and possibly the prefix
        index): `live` is deduplicated and every referencing table row is
        rewritten through one old->new map, so a moved shared block stays
        consistent for each holder.  The caller must remap the prefix index
        with the same map (`old_to_new(perm)`) in the same breath.
        """
        nb = self.cfg.num_blocks
        live: list[int] = [0]                        # null block stays put
        seen = {0}
        for lane in range(self.slots):
            for b in self.blocks_for(lane):          # skips released (0) slots
                if b not in seen:                    # dedup: shared blocks
                    seen.add(b)                      # appear in many tables
                    live.append(b)
        for b in range(1, nb):                       # index-only blocks are
            if self.ref_count[b] > 0 and b not in seen:   # live too
                seen.add(b)
                live.append(b)
        dead = [b for b in range(nb) if b not in seen]
        perm = np.asarray(live + dead, np.int32)
        assert perm.shape == (nb,)
        o2n = self.old_to_new(perm)
        for lane in range(self.slots):
            n = int(self.num_mapped[lane])
            if n:
                self.tables[lane, :n] = o2n[self.tables[lane, :n]]
        self.ref_count = self.ref_count[perm]
        self.index_ref = self.index_ref[perm]
        self.allocator.reset_free(list(range(nb - 1, len(live) - 1, -1)))
        return perm

    @staticmethod
    def old_to_new(perm: np.ndarray) -> np.ndarray:
        """Invert a defragment permutation into an old-id -> new-id map
        (what table rewrites and prefix-index remaps consume)."""
        o2n = np.empty(perm.shape[0], np.int64)
        o2n[perm] = np.arange(perm.shape[0])
        return o2n

    def check_invariants(self, index_held: "dict[int, int] | None" = None) -> None:
        """Test hook: refcounts must equal lane table mappings plus the
        index's claims, and the free list must be exactly the zero-ref
        blocks.  `index_held` maps block id -> index refs (0/1) as reported
        by the prefix index."""
        counts = np.zeros_like(self.ref_count)
        for lane in range(self.slots):
            for b in self.blocks_for(lane):
                counts[b] += 1
        counts[1:] += self.index_ref[1:].astype(np.int64)
        if index_held is not None:
            held = np.zeros_like(self.ref_count)
            for b, n in index_held.items():
                held[b] = n
            if not (held == self.index_ref.astype(np.int64)).all():
                raise AssertionError("prefix index claims != index_ref flags")
        if not (counts == self.ref_count).all():
            bad = np.nonzero(counts != self.ref_count)[0]
            raise AssertionError(
                f"refcount mismatch at blocks {bad.tolist()}: "
                f"mapped={counts[bad].tolist()} ref={self.ref_count[bad].tolist()}")
        free = sorted(self.allocator._free)
        zero = sorted(int(b) for b in range(1, self.cfg.num_blocks)
                      if self.ref_count[b] == 0)
        if free != zero:
            raise AssertionError(f"free list {free} != zero-ref blocks {zero}")


class GroupedPagedCache:
    """One `PagedKVCache` per layer group, behind the single-cache surface
    the scheduler drives.

    Groups bucket layers by attention reach (see
    `models.transformer.layer_group_keys`): `horizons[g]` is the group's
    sliding-window size or None for global reach.  Each group owns its own
    block-id space and tables, so `release_expired` reclaims a windowed
    group's blocks even while a global group pins full history — the paged
    pools for a gemma3-style 5-local:1-global stack plateau on the local
    layers instead of growing everywhere.

    `pending_copies` queues copy-on-write block copies as (group, src, dst)
    triples; the engine drains it into device pool copies at the start of
    each step, BEFORE any model write, so a forked block's contents are in
    place before the lane appends (and before a freed source id could be
    overwritten by this step's writes).
    """

    def __init__(self, *, slots: int, num_blocks: int, block_size: int,
                 max_blocks_per_seq: int,
                 horizons: "tuple[int | None, ...]" = (None,)):
        if not horizons:
            raise ValueError("need at least one layer group")
        self.groups = tuple(
            PagedKVCache(slots=slots, num_blocks=num_blocks,
                         block_size=block_size,
                         max_blocks_per_seq=max_blocks_per_seq)
            for _ in horizons)
        self.horizons = tuple(horizons)
        self.slots = slots
        self.pending_copies: "list[tuple[int, int, int]]" = []

    # ------------------------------------------------------------ queries
    @property
    def cfg(self) -> PagedCacheConfig:
        return self.groups[0].cfg

    @property
    def num_groups(self) -> int:
        return len(self.groups)

    @property
    def blocks_in_use(self) -> int:
        return sum(g.blocks_in_use for g in self.groups)

    @property
    def num_free(self) -> int:
        """Free blocks in the TIGHTEST group (the admission constraint)."""
        return min(g.num_free for g in self.groups)

    @property
    def blocks_shared(self) -> int:
        return sum(g.blocks_shared for g in self.groups)

    def blocks_for(self, lane: int) -> "tuple[list[int], ...]":
        return tuple(g.blocks_for(lane) for g in self.groups)

    def table_snapshot(self, lane: int, nblocks: int) -> "tuple[list[int], ...]":
        return tuple(g.table_snapshot(lane, nblocks) for g in self.groups)

    def blocks_needed(self, lane: int, upto_pos: int) -> int:
        return max(g.blocks_needed(lane, upto_pos) for g in self.groups)

    # --------------------------------------------------------- mutations
    def ensure(self, lane: int, upto_pos: int) -> bool:
        """Map blocks in EVERY group.  On a partial failure the groups
        already extended keep their mappings (they are needed regardless);
        the caller evicts/preempts and retries, and satisfied groups then
        need zero new blocks."""
        for g in self.groups:
            if not g.ensure(lane, upto_pos):
                return False
        return True

    def share_blocks(self, lane: int,
                     blocks_by_group: "tuple[list[int], ...]") -> None:
        lens = {len(b) for b in blocks_by_group}
        if len(blocks_by_group) != len(self.groups) or len(lens) != 1:
            raise ValueError("need one equal-length block list per group")
        for g, blocks in zip(self.groups, blocks_by_group):
            g.share_blocks(lane, blocks)

    def fork_tail(self, lane: int, logical: int) -> bool:
        """Copy-on-write the lane's `logical` table entry in every group,
        queueing pool copies.  False when some group's pool is dry — the
        caller rolls the tail share back (`drop_last_shared`); groups forked
        before the failure keep their (now exclusive) fresh blocks, which
        `drop_last_shared` then frees."""
        for gi, g in enumerate(self.groups):
            old = int(g.tables[lane, logical])
            if not old:
                continue            # null window coverage: nothing to fork
            new = g.fork_block(lane, logical)
            if new is None:
                return False
            if new != old:
                self.pending_copies.append((gi, old, new))
        return True

    def drop_last_shared(self, lane: int) -> None:
        dropped = []
        for gi, g in enumerate(self.groups):
            have = int(g.num_mapped[lane])
            dropped.append((gi, int(g.tables[lane, have - 1])))
            g.drop_last_shared(lane)
        # purge queued copies whose destination was just rolled back
        gone = set(dropped)
        self.pending_copies = [
            (gi, s, d) for gi, s, d in self.pending_copies
            if (gi, d) not in gone]

    def free_lane(self, lane: int) -> None:
        for g in self.groups:
            g.free_lane(lane)

    def release_expired(self, lane: int, pos: int) -> int:
        """Per-group window reclamation: each group with a finite horizon
        frees the lane's blocks wholly behind it; global groups keep
        everything.  Returns total blocks freed."""
        freed = 0
        for g, h in zip(self.groups, self.horizons):
            if h is not None:
                freed += g.release_expired(lane, pos, h)
        return freed

    def truncate_blocks(self, lane: int, keep_blocks: int) -> int:
        """Speculative rollback across every group (logical layouts are
        identical, so one keep-count serves all).  Returns blocks freed."""
        return sum(g.truncate_blocks(lane, keep_blocks) for g in self.groups)

    def assert_writable(self, lane: int, start_pos: int, end_pos: int) -> None:
        for g in self.groups:
            g.assert_writable(lane, start_pos, end_pos)

    def defragment(self) -> "tuple[np.ndarray, ...]":
        """Compact every group's pool; returns one permutation per group.
        The engine permutes each group's device pools with its perm and
        remaps the prefix index with `PagedKVCache.old_to_new(perm)`."""
        return tuple(g.defragment() for g in self.groups)

    def check_invariants(self,
                         index_held: "tuple[dict[int, int], ...] | None" = None
                         ) -> None:
        for gi, g in enumerate(self.groups):
            g.check_invariants(index_held[gi] if index_held else None)
