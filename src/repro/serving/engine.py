"""Paged-KV serving engine: chunked prefill + continuous batching decode.

Composes `serving.cache.PagedKVCache` (fixed-size KV blocks shared across
lanes, per-lane block tables) with `serving.scheduler.ChunkedPrefillScheduler`
(FCFS + preemption-by-block-pressure, prefill split into fixed chunks and
interleaved with decode — the generalized-ping-pong schedule applied to the
request stream, so per-step token count and HBM traffic stay flat).

Exactly TWO step shapes are jit-compiled, independent of prompt lengths:

  * `prefill_chunk`: (1, chunk) tokens — one chunk of one lane's (padded)
    prompt, writing whole KV blocks through the lane's block table;
  * `decode_step_paged`: (slots, 1) tokens with PER-LANE position vectors —
    heterogeneous lanes decode in one call (the seed engine ran one call per
    distinct position and re-traced per prompt length).

Sampling is deterministic: greedy by default; with temperature > 0 every
token draw uses a key folded from (ServeConfig.seed, request id, token
index), so identical request streams reproduce identical outputs regardless
of lane assignment, step interleaving, or preemption/resume.

Per-step metrics (tokens, blocks in use, queue depth, projected HBM bytes)
accumulate in `engine.metrics`; `benchmarks/run.py` records them into
BENCH_serving.json.

Recurrent architectures (mamba/xlstm blocks: O(1) state, no paged KV) are
served by `serving.dense_engine.DenseServingEngine` — see `make_engine`.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.schedule import plan_serve_chunk, round_up, tokens_per_step_cov
from repro.models import transformer as tf
from repro.serving.cache import PagedKVCache
from repro.serving.scheduler import ChunkedPrefillScheduler, Request

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    slots: int = 4                 # concurrent decode lanes
    max_len: int = 256             # max tokens per sequence (table capacity)
    temperature: float = 0.0       # 0 => greedy
    eos_token: int | None = None
    dense_kernel: str | None = None  # override cfg.dense_kernel at serve time;
                                     # threads through prefill AND decode, so
                                     # "kernel" streams every projection
                                     # through the GPP Pallas matmul
    paged_attn_kernel: str | None = None  # override cfg.paged_attn_kernel:
                                     # auto | pallas | interpret | ref — the
                                     # paged READ path ("pallas" streams KV
                                     # blocks through the VMEM-ring kernel
                                     # instead of gathering pools)
    seed: int = 0                  # PRNG root for temperature sampling;
                                   # per-token keys fold in (rid, token_idx)
    # paged-KV knobs (0 = derive from the ModelConfig serving defaults)
    block_size: int = 0            # tokens per KV block
    num_blocks: int = 0            # pool size incl. reserved null block 0;
                                   # 0 = slots*max_len worth (the dense
                                   # engine's footprint, now SHARED)
    prefill_chunk: int = 0         # tokens per prefill chunk; 0 = planned by
                                   # core.schedule.plan_serve_chunk
    token_budget: int = 0          # flat per-step token target; 0 = cfg /
                                   # slots + 2 blocks


def sample_token(serve: ServeConfig, rid: int, token_idx: int,
                 logits_row) -> int:
    """Deterministic sampling shared by both engines: greedy at
    temperature 0, otherwise a categorical draw keyed on
    (serve.seed, rid, token_idx) — no shared/implicit PRNG state, so
    identical request streams reproduce identical outputs regardless of
    lane assignment, batching, or preemption/resume."""
    if serve.temperature <= 0.0:
        return int(np.argmax(np.asarray(logits_row, np.float32)))
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(serve.seed), rid), token_idx)
    scaled = jnp.asarray(logits_row, jnp.float32) / serve.temperature
    return int(jax.random.categorical(key, scaled))


class ServingEngine:
    """Paged-KV continuous-batching engine (see module docstring)."""

    def __init__(self, cfg: ModelConfig, params: Pytree, serve: ServeConfig):
        if serve.dense_kernel is not None:
            cfg = cfg.with_(dense_kernel=serve.dense_kernel)
        if serve.paged_attn_kernel is not None:
            cfg = cfg.with_(paged_attn_kernel=serve.paged_attn_kernel)
        if not tf.supports_paged(cfg):
            raise ValueError(
                f"{cfg.name} has recurrent/cross blocks; paged serving "
                "covers attention-cache models — use DenseServingEngine "
                "(serving.make_engine picks automatically)")
        self.cfg = cfg
        self.params = params
        self.serve = serve

        bs = serve.block_size or cfg.serve_block_size
        max_len = round_up(serve.max_len, bs)
        mb = max_len // bs
        budget = serve.token_budget or cfg.serve_token_budget \
            or (serve.slots + 2 * bs)
        chunk = serve.prefill_chunk or plan_serve_chunk(
            token_budget=budget, decode_lanes=serve.slots, block_size=bs)
        num_blocks = serve.num_blocks or serve.slots * mb + 1
        self.block_size = bs
        self.chunk = chunk
        self.token_budget = budget

        self.kv = PagedKVCache(slots=serve.slots, num_blocks=num_blocks,
                               block_size=bs, max_blocks_per_seq=mb)
        self.scheduler = ChunkedPrefillScheduler(
            self.kv, slots=serve.slots, chunk=chunk)
        specs = tf.paged_cache_specs(cfg, num_blocks, bs)
        self.caches = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), specs)
        self._kv_token_bytes = self._kv_bytes_per_token(specs)
        self._param_bytes = cfg.active_params() * cfg.jdtype.itemsize
        # resolved paged-attention read path ("ref" gathers pools, else the
        # streaming kernel) — recorded so benchmarks can attribute bytes
        from repro.kernels.ops import resolve_paged_attn_mode
        self.paged_attn_mode = resolve_paged_attn_mode(cfg.paged_attn_kernel)
        # sliding-window block reclamation: finite only when every layer is
        # windowed (tables are shared across layers) — see window_horizon
        self.window_horizon = tf.window_horizon(cfg)

        # trace_counts increments when jax TRACES (= compiles) a step fn —
        # the re-jit regression tests assert it stays at {1, 1} across
        # arbitrary prompt-length mixes.
        self.trace_counts = {"prefill_chunk": 0, "decode": 0}

        def _prefill(params, caches, toks, table_row, start_pos, last_idx):
            self.trace_counts["prefill_chunk"] += 1
            return tf.prefill_chunk(params, cfg, toks, caches, table_row,
                                    start_pos, last_idx)

        def _decode(params, caches, toks, tables, positions, active):
            self.trace_counts["decode"] += 1
            return tf.decode_step_paged(params, cfg, toks, caches, tables,
                                        positions, active)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

        self._results: dict[int, list[int]] = {}
        self._next_id = 0
        self.metrics: list[dict] = []

    @staticmethod
    def _kv_bytes_per_token(specs) -> int:
        """Per-token KV bytes across every attention layer (stacked block
        leaves carry a leading superblock dim before (nb, bs, ...))."""
        total = 0

        def leaf(path, s):
            nonlocal total
            stacked = tf.is_stacked_cache_path(path)
            per_slot = int(np.prod(s.shape[3:] if stacked else s.shape[2:]))
            layers = s.shape[0] if stacked else 1
            total += layers * per_slot * jnp.dtype(s.dtype).itemsize

        jax.tree_util.tree_map_with_path(leaf, specs)
        return total

    # ---------------------------------------------------------------- API
    def submit(self, prompt: "list[int]", max_new_tokens: int = 32) -> int:
        rid = self._next_id
        self._next_id += 1
        self.scheduler.submit(Request(
            rid=rid, prompt=np.asarray(prompt, np.int32),
            max_new=max_new_tokens))
        return rid

    def result(self, rid: int) -> "list[int] | None":
        return self._results.get(rid)

    @property
    def pending(self) -> int:
        return self.scheduler.pending

    def flatness_cov(self) -> float:
        """Coefficient of variation of tokens/step (lower = flatter)."""
        return tokens_per_step_cov([m["tokens"] for m in self.metrics])

    # ------------------------------------------------------------ engine
    def _sample(self, logits_row, req: Request) -> int:
        return sample_token(self.serve, req.rid, len(req.produced), logits_row)

    def _maybe_finish(self, lane: int, tok: int) -> None:
        req = self.scheduler.request_at(lane)
        done = req.remaining <= 0 or (
            self.serve.eos_token is not None and tok == self.serve.eos_token)
        if done:
            self._results[req.rid] = list(req.produced)
            self.scheduler.finish(lane)

    def step(self) -> bool:
        """One engine step: at most one prefill chunk + one batched decode
        call over every decode-phase lane."""
        plan = self.scheduler.schedule()
        if plan is None:
            if self.scheduler.pending:
                raise RuntimeError(
                    "paged pool cannot back even the oldest request "
                    f"({self.kv.cfg.num_blocks} blocks of {self.block_size}); "
                    "raise ServeConfig.num_blocks")
            return False
        prefill_tokens = decode_tokens = 0
        read_tokens = 0
        # per-call attention-read accounting: the gather path materializes
        # every participant's full (MB*bs) logical sequence in HBM; the
        # streaming kernel only moves each participant's LIVE blocks through
        # VMEM (unmapped/released entries re-read the hot null block).
        attn_rows_gather = attn_rows_stream = 0
        mb_rows = self.kv.cfg.max_blocks_per_seq * self.block_size

        def _live_rows(lane: int) -> int:
            return len(self.kv.blocks_for(lane)) * self.block_size

        if plan.prefill:
            w = plan.prefill
            req = self.scheduler.request_at(w.lane)
            logits, self.caches = self._prefill(
                self.params, self.caches,
                jnp.asarray(w.tokens[None]),
                jnp.asarray(self.kv.tables[w.lane][None]),
                w.start_pos, w.last_idx)
            prefill_tokens = len(w.tokens)
            read_tokens += w.start_pos + len(w.tokens)
            attn_rows_gather += mb_rows
            attn_rows_stream += _live_rows(w.lane)
            if self.window_horizon and w.real_tokens:
                self.kv.release_expired(
                    w.lane, w.start_pos + w.real_tokens - 1,
                    self.window_horizon)
            if w.final:
                tok = self._sample(logits[0], req)
                req.produced.append(tok)
                self.scheduler.to_decode(w.lane)
                self._maybe_finish(w.lane, tok)

        if plan.decode_lanes:
            slots = self.serve.slots
            toks = np.zeros((slots, 1), np.int32)
            positions = np.zeros((slots,), np.int32)
            active = np.zeros((slots,), bool)
            for lane in plan.decode_lanes:
                req = self.scheduler.request_at(lane)
                toks[lane, 0] = req.produced[-1]
                positions[lane] = req.decode_pos
                active[lane] = True
                read_tokens += req.decode_pos + 1
            logits, self.caches = self._decode(
                self.params, self.caches, jnp.asarray(toks),
                jnp.asarray(self.kv.tables), jnp.asarray(positions),
                jnp.asarray(active))
            attn_rows_gather += slots * mb_rows
            attn_rows_stream += sum(_live_rows(l) for l in range(slots))
            logits_np = np.asarray(logits, np.float32)
            for lane in plan.decode_lanes:
                req = self.scheduler.request_at(lane)
                req.decode_pos += 1
                tok = self._sample(logits_np[lane, 0], req)
                req.produced.append(tok)
                if self.window_horizon:
                    self.kv.release_expired(lane, req.decode_pos,
                                            self.window_horizon)
                self._maybe_finish(lane, tok)
            decode_tokens = len(plan.decode_lanes)

        tokens = prefill_tokens + decode_tokens
        self.metrics.append({
            "step": len(self.metrics),
            "tokens": tokens,
            "prefill_tokens": prefill_tokens,
            # non-pad prompt tokens in the chunk (<= prefill_tokens; the
            # padded count is the flatness/traffic quantity)
            "prefill_real_tokens": (plan.prefill.real_tokens
                                    if plan.prefill else 0),
            "decode_tokens": decode_tokens,
            "blocks_in_use": self.kv.blocks_in_use,
            "free_blocks": self.kv.num_free,
            "queue_depth": self.scheduler.queue_depth,
            "preempted": len(plan.preempted),
            # projection: weights stream once per step; every processed token
            # writes its KV; reads cover each participant's live prefix
            "hbm_bytes": (self._param_bytes
                          + tokens * self._kv_token_bytes
                          + read_tokens * self._kv_token_bytes),
            # attention-read traffic this step, per read-path:
            # gather = HBM bytes MATERIALIZED by `_paged_gather` (every
            # participant's full MB*bs logical sequence, per layer);
            # stream = bytes the Pallas kernel DMAs through the VMEM ring —
            # it skips blocks outside each lane's visible range, so this is
            # each participant's LIVE blocks (approximate across layers:
            # window layers skip expired blocks even when a full-attention
            # layer in the same model still reads them)
            "attn_bytes_gather": attn_rows_gather * self._kv_token_bytes,
            "attn_bytes_stream": attn_rows_stream * self._kv_token_bytes,
        })
        return True

    def defragment(self) -> None:
        """Compact the physical pool (gathers then touch one dense prefix);
        pools are permuted in lockstep with the tables."""
        perm = self.kv.defragment()
        jperm = jnp.asarray(perm)

        def apply(path, pool):
            return (pool[:, jperm] if tf.is_stacked_cache_path(path)
                    else pool[jperm])

        self.caches = jax.tree_util.tree_map_with_path(apply, self.caches)

    def run(self, max_steps: int = 10_000):
        steps = 0
        while self.pending and steps < max_steps:
            self.step()
            steps += 1
        return self._results


def make_engine(cfg: ModelConfig, params: Pytree, serve: ServeConfig):
    """Paged engine when the architecture supports it, dense-cache fallback
    (recurrent/cross blocks) otherwise."""
    if tf.supports_paged(cfg if serve.dense_kernel is None
                         else cfg.with_(dense_kernel=serve.dense_kernel)):
        return ServingEngine(cfg, params, serve)
    from repro.serving.dense_engine import DenseServingEngine
    return DenseServingEngine(cfg, params, serve)
