"""Paged-KV serving engine: chunked prefill + continuous batching decode.

Composes `serving.cache.GroupedPagedCache` (fixed-size KV blocks shared
across lanes, one block table per layer group — global vs sliding-window
reach) with `serving.scheduler.ChunkedPrefillScheduler` (FCFS + preemption-
by-block-pressure, prefill split into fixed chunks and interleaved with
decode — the generalized-ping-pong schedule applied to the request stream,
so per-step token count and HBM traffic stay flat), and optionally
`serving.prefix.PrefixCache` (radix-tree shared-prefix KV reuse: admission
maps previously computed prompt-prefix blocks straight into the lane's
tables and prefill skips those chunks entirely — the redundant re-prefill
bytes never cross HBM).

Exactly THREE step shapes are jit-compiled, independent of prompt lengths,
draft lengths, and acceptance patterns:

  * `prefill_chunk`: (1, chunk) tokens — one chunk of one lane's (padded)
    prompt, scattering per-token KV writes through the lane's block tables
    (per-token because a prefix-cache hit may start a chunk mid-block);
  * `decode_step_paged`: (slots, 1) tokens with PER-LANE position vectors —
    heterogeneous lanes decode in one call (the seed engine ran one call per
    distinct position and re-traced per prompt length);
  * `verify_step_paged`: (slots, draft_len+1) tokens — the speculative-
    decoding verify burst (ServeConfig.speculation): drafts mined from the
    request's own history / the prefix radix tree (`ngram_propose` /
    `PrefixCache.suffix_lookup` — no weights streamed to draft) or from an
    optional small draft model are scored in ONE forward pass, so the
    streamed weight working set is amortized over up to draft_len+1 tokens
    per lane instead of 1.  Per-lane shorter drafts are masked by an
    `nvalid` vector (spare rows write null block 0), so one shape covers
    every acceptance pattern; steps where no lane drafted use the plain
    decode shape.  Rejected drafts roll back by block-table truncation
    (`GroupedPagedCache.truncate_blocks`) — stale pool rows are hidden by
    the position-exact masks and overwritten in place, the prefill-pad
    argument again.  Accepted-token bursts stay under the scheduler's flat
    token budget (`core.schedule.plan_verify_budget`), and the output
    stream is token-for-token identical with speculation on or off.

Sampling is deterministic: greedy by default; with temperature > 0 every
token draw uses a key folded from (ServeConfig.seed, request id, token
index), so identical request streams reproduce identical outputs regardless
of lane assignment, step interleaving, preemption/resume, or prefix-cache
hits.

Per-step metrics (tokens, blocks in use/shared, prefix hit tokens, queue
depth, projected HBM bytes) accumulate in `engine.metrics`;
`benchmarks/run.py` records them into BENCH_serving.json.

Recurrent architectures (mamba/xlstm blocks: O(1) state, no paged KV) are
served by `serving.dense_engine.DenseServingEngine` — see `make_engine`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.schedule import plan_serve_chunk, round_up, tokens_per_step_cov
from repro.kernels.gpp_matmul import matmul_lane_events
from repro.kernels.paged_attention import paged_lane_events
from repro.models import transformer as tf
from repro.obs import make_telemetry
from repro.obs.ledger import BandwidthLedger
from repro.obs.trace import (PID_KERNEL, PID_REQUESTS, PID_SERVING,
                             TID_ENGINE, TID_LANE0, annotate_serving_tracks)
from repro.serving.cache import GroupedPagedCache, PagedKVCache
from repro.serving.prefix import PrefixCache, ngram_propose
from repro.serving.scheduler import ChunkedPrefillScheduler, Request

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    slots: int = 4                 # concurrent decode lanes
    max_len: int = 256             # max tokens per sequence (table capacity)
    temperature: float = 0.0       # 0 => greedy
    eos_token: int | None = None
    dense_kernel: str | None = None  # override cfg.dense_kernel at serve time;
                                     # threads through prefill AND decode, so
                                     # "kernel" streams every projection
                                     # through the GPP Pallas matmul
    paged_attn_kernel: str | None = None  # override cfg.paged_attn_kernel:
                                     # auto | pallas | interpret | ref — the
                                     # paged READ path ("pallas" streams KV
                                     # blocks through the VMEM-ring kernel
                                     # instead of gathering pools)
    seed: int = 0                  # PRNG root for temperature sampling;
                                   # per-token keys fold in (rid, token_idx)
    # paged-KV knobs (0 = derive from the ModelConfig serving defaults)
    block_size: int = 0            # tokens per KV block
    num_blocks: int = 0            # pool size PER LAYER GROUP incl. reserved
                                   # null block 0; 0 = slots*max_len worth
                                   # (the dense engine's footprint, now
                                   # SHARED across lanes)
    prefill_chunk: int = 0         # tokens per prefill chunk; 0 = planned by
                                   # core.schedule.plan_serve_chunk
    token_budget: int = 0          # flat per-step token target; 0 = cfg /
                                   # slots + 2 blocks
    # shared-prefix KV reuse (serving/prefix.py); None = cfg.prefix_cache /
    # cfg.prefix_cache_blocks
    prefix_cache: "bool | None" = None
    prefix_cache_blocks: "int | None" = None
    # speculative decoding (paged engine only); None/0 = cfg.speculation /
    # cfg.draft_len.  draft_source picks the proposal mechanism: "self" =
    # prompt-lookup n-grams over the lane's own history with a fallback to
    # the prefix radix tree's stored sequences (no extra weights streamed);
    # "model" = greedy rollout of a small draft model passed to the engine
    # (make_engine / ServingEngine `draft_model=(cfg, params)`), falling
    # back to "self" when none was provided.
    speculation: "bool | None" = None
    draft_len: int = 0
    draft_source: str = "self"
    # observability (repro.obs): request/kernel trace spans + TTFT/TPOT
    # histograms + per-step wall times in the ledger.  None = cfg.obs;
    # trace_capacity 0 = cfg.obs_trace_capacity; metrics_retention None =
    # cfg.metrics_retention (ledger rows kept; 0 = unbounded).
    obs: "bool | None" = None
    trace_capacity: int = 0
    metrics_retention: "int | None" = None


@functools.partial(jax.jit, donate_argnums=(0,))
def _pool_copy(pool, src, dst):
    """One COW block copy in a flat (nb, bs, ...) pool.  src/dst are traced
    scalars, so every pool shape compiles exactly once per process; the
    pool buffer is DONATED (the engine rebinds self.caches immediately), so
    on accelerators this lowers to an in-place one-block update instead of
    materializing a whole new pool per copy."""
    return pool.at[dst].set(pool[src])


@functools.partial(jax.jit, donate_argnums=(0,))
def _pool_copy_stacked(pool, src, dst):
    """Same for stacked (S, nb, bs, ...) superblock pools."""
    return pool.at[:, dst].set(pool[:, src])


def sample_token(serve: ServeConfig, rid: int, token_idx: int,
                 logits_row) -> int:
    """Deterministic sampling shared by both engines: greedy at
    temperature 0, otherwise a categorical draw keyed on
    (serve.seed, rid, token_idx) — no shared/implicit PRNG state, so
    identical request streams reproduce identical outputs regardless of
    lane assignment, batching, or preemption/resume."""
    if serve.temperature <= 0.0:
        return int(np.argmax(np.asarray(logits_row, np.float32)))
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(serve.seed), rid), token_idx)
    scaled = jnp.asarray(logits_row, jnp.float32) / serve.temperature
    return int(jax.random.categorical(key, scaled))


class ServingEngine:
    """Paged-KV continuous-batching engine (see module docstring)."""

    def __init__(self, cfg: ModelConfig, params: Pytree, serve: ServeConfig,
                 draft_model: "tuple[ModelConfig, Pytree] | None" = None):
        if serve.dense_kernel is not None:
            cfg = cfg.with_(dense_kernel=serve.dense_kernel)
        if serve.paged_attn_kernel is not None:
            cfg = cfg.with_(paged_attn_kernel=serve.paged_attn_kernel)
        if not tf.supports_paged(cfg):
            raise ValueError(
                f"{cfg.name} has recurrent/cross blocks; paged serving "
                "covers attention-cache models — use DenseServingEngine "
                "(serving.make_engine picks automatically)")
        self.cfg = cfg
        self.params = params
        self.serve = serve

        bs = serve.block_size or cfg.serve_block_size
        max_len = round_up(serve.max_len, bs)
        mb = max_len // bs
        budget = serve.token_budget or cfg.serve_token_budget \
            or (serve.slots + 2 * bs)
        chunk = serve.prefill_chunk or plan_serve_chunk(
            token_budget=budget, decode_lanes=serve.slots, block_size=bs)
        num_blocks = serve.num_blocks or serve.slots * mb + 1
        self.block_size = bs
        self.chunk = chunk
        self.token_budget = budget

        # layers bucketed by attention reach: one block table + block-id
        # space per group, so `release_expired` frees a windowed group's
        # blocks while a global group keeps full history
        self.group_keys = tf.layer_group_keys(cfg)
        self.group_horizons = tf.group_horizons(cfg)
        self.kv = GroupedPagedCache(
            slots=serve.slots, num_blocks=num_blocks, block_size=bs,
            max_blocks_per_seq=mb, horizons=self.group_horizons)

        prefix_on = (serve.prefix_cache if serve.prefix_cache is not None
                     else cfg.prefix_cache)
        prefix_blocks = (serve.prefix_cache_blocks
                         if serve.prefix_cache_blocks is not None
                         else cfg.prefix_cache_blocks)
        self.prefix = (PrefixCache(self.kv, max_blocks=prefix_blocks)
                       if prefix_on else None)

        # speculative decoding: drafts mined host-side (or by a small draft
        # model), verified in one batched (slots, draft_len+1) call
        spec_on = (serve.speculation if serve.speculation is not None
                   else cfg.speculation)
        self.draft_len = (serve.draft_len or cfg.draft_len) if spec_on else 0
        self.draft_source = serve.draft_source
        self._draft_cfg = self._draft_params = None
        if draft_model is not None and spec_on \
                and serve.draft_source == "model":
            self._draft_cfg, self._draft_params = draft_model
            self._draft_window = 16      # fixed (1, W) rollout shape: one
            #                              compile regardless of context len
            dcfg = self._draft_cfg

            def _draft_fwd(params, toks):
                return tf.forward(params, dcfg, {"tokens": toks})

            self._draft_fwd = jax.jit(_draft_fwd)

        # telemetry (repro.obs): disabled handle = one attribute check per
        # instrumentation site; enabled = trace spans + TTFT/TPOT histograms
        obs_on = serve.obs if serve.obs is not None else cfg.obs
        self.obs = make_telemetry(
            obs_on,
            trace_capacity=serve.trace_capacity or cfg.obs_trace_capacity)
        annotate_serving_tracks(self.obs.trace, serve.slots)
        self._kv_lane_calls = 0

        self.scheduler = ChunkedPrefillScheduler(
            self.kv, slots=serve.slots, chunk=chunk, prefix=self.prefix,
            draft_len=self.draft_len,
            draft_fn=self._draft_for if self.draft_len else None,
            token_budget=budget, trace=self.obs.trace)
        specs = tf.paged_cache_specs(cfg, num_blocks, bs)
        self.caches = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), specs)
        self._kv_token_bytes = self._kv_bytes_per_token(specs)
        self._group_token_bytes = self._kv_bytes_by_group(cfg, specs)
        self._param_bytes = cfg.active_params() * cfg.jdtype.itemsize
        # resolved paged-attention read path ("ref" gathers pools, else the
        # streaming kernel) — recorded so benchmarks can attribute bytes
        from repro.kernels.ops import resolve_paged_attn_mode
        self.paged_attn_mode = resolve_paged_attn_mode(cfg.paged_attn_kernel)
        # whole-model reclamation horizon kept for the all-window case and
        # back-compat introspection; per-group reclamation supersedes it
        self.window_horizon = tf.window_horizon(cfg)
        self._reclaims = any(h is not None for h in self.group_horizons)

        # trace_counts increments when jax TRACES (= compiles) a step fn —
        # the re-jit regression tests assert it stays at {1, 1, 1} across
        # arbitrary prompt-length / draft-length / acceptance mixes
        # ("verify" stays 0 with speculation off).
        self.trace_counts = {"prefill_chunk": 0, "decode": 0, "verify": 0}

        def _prefill(params, caches, toks, table_rows, start_pos, last_idx):
            self.trace_counts["prefill_chunk"] += 1
            return tf.prefill_chunk(params, cfg, toks, caches, table_rows,
                                    start_pos, last_idx)

        def _decode(params, caches, toks, tables, positions, active):
            self.trace_counts["decode"] += 1
            return tf.decode_step_paged(params, cfg, toks, caches, tables,
                                        positions, active)

        def _verify(params, caches, toks, tables, positions, active, nvalid):
            self.trace_counts["verify"] += 1
            return tf.verify_step_paged(params, cfg, toks, caches, tables,
                                        positions, active, nvalid)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)
        self._verify = jax.jit(_verify)

        self._results: dict[int, list[int]] = {}
        self._next_id = 0
        # the typed step ledger IS `metrics` (list-compatible: len / iter /
        # int+slice indexing), with optional bounded retention
        self.metrics = BandwidthLedger(retention=(
            serve.metrics_retention if serve.metrics_retention is not None
            else cfg.metrics_retention))

    @staticmethod
    def _kv_bytes_per_token(specs) -> int:
        """Per-token KV bytes across every attention layer (stacked block
        leaves carry a leading superblock dim before (nb, bs, ...))."""
        total = 0

        def leaf(path, s):
            nonlocal total
            stacked = tf.is_stacked_cache_path(path)
            per_slot = int(np.prod(s.shape[3:] if stacked else s.shape[2:]))
            layers = s.shape[0] if stacked else 1
            total += layers * per_slot * jnp.dtype(s.dtype).itemsize

        jax.tree_util.tree_map_with_path(leaf, specs)
        return total

    @staticmethod
    def _kv_bytes_by_group(cfg, specs) -> "list[int]":
        """Per-token KV bytes split by layer group (for per-group read
        accounting: a window group's live blocks differ from a global
        group's)."""
        out = [0] * len(tf.layer_group_keys(cfg))

        def leaf(path, s):
            stacked = tf.is_stacked_cache_path(path)
            per_slot = int(np.prod(s.shape[3:] if stacked else s.shape[2:]))
            layers = s.shape[0] if stacked else 1
            out[tf.cache_path_group(cfg, path)] += \
                layers * per_slot * jnp.dtype(s.dtype).itemsize

        jax.tree_util.tree_map_with_path(leaf, specs)
        return out

    # ---------------------------------------------------------------- API
    def submit(self, prompt: "list[int]", max_new_tokens: int = 32) -> int:
        rid = self._next_id
        self._next_id += 1
        self.scheduler.submit(Request(
            rid=rid, prompt=np.asarray(prompt, np.int32),
            max_new=max_new_tokens))
        self.obs.requests.on_submit(rid)
        if self.obs.enabled:
            self.obs.trace.async_begin(
                f"req {rid}", rid, pid=PID_REQUESTS,
                args={"prompt_tokens": len(prompt),
                      "max_new": max_new_tokens})
        return rid

    def result(self, rid: int) -> "list[int] | None":
        return self._results.get(rid)

    @property
    def pending(self) -> int:
        return self.scheduler.pending

    def flatness_cov(self) -> float:
        """Coefficient of variation of tokens/step (lower = flatter)."""
        return tokens_per_step_cov([m["tokens"] for m in self.metrics])

    def prefix_hit_rate(self) -> float:
        return self.prefix.hit_rate() if self.prefix else 0.0

    def acceptance_rate(self) -> float:
        """Accepted / drafted tokens over the engine's lifetime (0.0 with
        speculation off or nothing drafted yet).  Ledger totals, so the
        rate stays lifetime-exact under bounded metrics retention."""
        drafted = self.metrics.total("drafted_tokens")
        accepted = self.metrics.total("accepted_tokens")
        return accepted / drafted if drafted else 0.0

    # ------------------------------------------------------------ engine
    def _sample(self, logits_row, req: Request) -> int:
        return sample_token(self.serve, req.rid, len(req.produced), logits_row)

    # ---------------------------------------------------------- drafting
    def _draft_for(self, req: Request, cap: int) -> np.ndarray:
        """Scheduler hook: propose up to `cap` draft tokens for `req`.

        "self" drafting is pure token statistics over tokens the system has
        already seen — the lane's own prompt+produced history first
        (`ngram_propose`), then the prefix radix tree's stored sequences
        (`PrefixCache.suffix_lookup`, cross-request repetition) — so no
        weights are streamed to produce the guess.  "model" drafting rolls
        out the small draft model greedily.  Wrong drafts only cost their
        share of one verify pass; the acceptance loop keeps the emitted
        stream exact either way."""
        if cap < 1:
            return np.zeros((0,), np.int32)
        hist = np.concatenate(
            [req.prompt, np.asarray(req.produced, np.int32)])
        if self._draft_params is not None:
            d = self._draft_with_model(hist, cap)
        else:
            d = ngram_propose(hist, cap)
            if len(d) == 0 and self.prefix is not None:
                d = self.prefix.suffix_lookup(hist, cap)
        d = np.asarray(d, np.int32)[:cap]
        if len(d):
            # a draft model with a different vocab may propose ids the
            # target can't embed; clamp — a wrong guess is just rejected
            d = np.clip(d, 0, self.cfg.vocab_size - 1)
        return d

    def _draft_with_model(self, hist: np.ndarray, cap: int) -> np.ndarray:
        """Greedy draft-model rollout over a fixed (1, W) token window —
        one compiled shape regardless of context length; the rollout cost
        is the draft model's (small) weight stream, repaid when accepted
        tokens amortize the TARGET model's stream."""
        W = self._draft_window
        seq = [int(t) for t in hist[-W:]]
        out: "list[int]" = []
        for _ in range(cap):
            window = seq[-W:]
            n = len(window)
            toks = np.zeros((1, W), np.int32)
            toks[0, :n] = window
            logits = self._draft_fwd(self._draft_params, jnp.asarray(toks))
            t = int(np.argmax(np.asarray(logits[0, n - 1], np.float32)))
            out.append(t)
            seq.append(t)
        return np.asarray(out, np.int32)

    def _tables_jnp(self, lane: "int | None" = None):
        """Per-group block tables as a jit-stable tuple: the whole (slots,
        MB) table per group for decode, or one lane's (1, MB) row per group
        for a prefill chunk."""
        if lane is None:
            return tuple(jnp.asarray(g.tables) for g in self.kv.groups)
        return tuple(jnp.asarray(g.tables[lane][None])
                     for g in self.kv.groups)

    def _apply_pending_copies(self) -> None:
        """Drain queued copy-on-write block copies into the device pools —
        BEFORE any model call of this step, so forked blocks carry their
        source rows before the lane appends (and before a freed source id
        can be overwritten by this step's writes)."""
        if not self.kv.pending_copies:
            return
        per_group: "dict[int, list[tuple[int, int]]]" = {}
        for gi, src, dst in self.kv.pending_copies:
            per_group.setdefault(gi, []).append((src, dst))
        self.kv.pending_copies = []

        def apply(path, pool):
            copies = per_group.get(tf.cache_path_group(self.cfg, path))
            if not copies:
                return pool
            op = (_pool_copy_stacked if tf.is_stacked_cache_path(path)
                  else _pool_copy)
            for src, dst in copies:
                pool = op(pool, np.int32(src), np.int32(dst))
            return pool

        self.caches = jax.tree_util.tree_map_with_path(apply, self.caches)

    def _prefix_insert(self, lane: int, tokens: np.ndarray) -> None:
        """Index `tokens` (every position's KV is written for this lane)
        into the radix tree, adopting the lane's novel blocks.  Called when
        a lane's prefill completes (its context becomes shareable while it
        still decodes) and again at finish (context + generated tokens,
        for multi-turn reuse)."""
        if self.prefix is None or len(tokens) == 0:
            return
        n = -(-len(tokens) // self.block_size)
        self.prefix.insert(np.asarray(tokens, np.int32),
                           self.kv.table_snapshot(lane, n))

    def _maybe_finish(self, lane: int, tok: int) -> None:
        req = self.scheduler.request_at(lane)
        done = req.remaining <= 0 or (
            self.serve.eos_token is not None and tok == self.serve.eos_token)
        if done:
            if self.prefix is not None:
                # KV exists for every fed token: prompt + produced[:-1]
                # (the final sampled token was never fed back)
                fed = np.concatenate(
                    [req.prompt, np.asarray(req.produced[:-1], np.int32)])
                self._prefix_insert(lane, fed)
            self._results[req.rid] = list(req.produced)
            self.obs.requests.on_finish(req.rid, len(req.produced))
            if self.obs.enabled:
                self.obs.trace.async_end(
                    f"req {req.rid}", req.rid, pid=PID_REQUESTS,
                    args={"tokens": len(req.produced),
                          "preemptions": req.preemptions})
            self.scheduler.finish(lane)
            if self.prefix is not None:
                # the lane's refs just dropped: the block cap can now bite
                self.prefix.enforce_cap()

    # ------------------------------------------------------ trace helpers
    # Modeled kernel lanes are emitted for every KV_LANE_STRIDE-th batched
    # call, not every call: they replay a deterministic chunk schedule, so
    # consecutive decode steps produce near-identical lanes, and emitting
    # all of them dominates the telemetry cost (the <5% overhead budget in
    # benchmarks/run.py:bench_serving_observability_overhead).
    KV_LANE_STRIDE = 8

    def _kv_lane_events(self, t0: float, t1: float, lanes) -> None:
        """Modeled DMA/compute kernel lanes for one paged-attention call:
        the chunk-issue schedule replayed over each participant's live
        blocks, stretched into the measured call window (cat="modeled" —
        see `kernels.paged_attention.paged_lane_events`).  Sampled every
        KV_LANE_STRIDE-th call."""
        self._kv_lane_calls += 1
        if (self._kv_lane_calls - 1) % self.KV_LANE_STRIDE:
            return
        g0 = self.kv.groups[0]
        counts = [len(g0.blocks_for(l)) if l in lanes else 0
                  for l in range(self.serve.slots)]
        paged_lane_events(
            self.obs.trace, counts, self.kv.cfg.max_blocks_per_seq,
            block_bytes=self.block_size * sum(self._group_token_bytes),
            t0_us=t0, dur_us=t1 - t0, pid=PID_KERNEL)

    def _trace_prefill(self, w, req, t0: float) -> None:
        """Prefill-chunk span on the lane's track + modeled lanes for the
        chunk's dense weight-streaming matmul (the GPP GeMM schedule)."""
        t1 = self.obs.now_us()
        self.obs.trace.complete(
            "prefill_chunk", t0, t1 - t0, pid=PID_SERVING,
            tid=TID_LANE0 + w.lane, cat="phase",
            args={"rid": req.rid, "tokens": len(w.tokens),
                  "real_tokens": w.real_tokens, "start_pos": w.start_pos,
                  "final": w.final})
        matmul_lane_events(
            self.obs.trace, len(w.tokens), self.cfg.d_model,
            self.cfg.d_model, itemsize=self.cfg.jdtype.itemsize,
            t0_us=t0, dur_us=t1 - t0, pid=PID_KERNEL)

    def _trace_batched(self, name: str, lanes, t0: float,
                       tokens: int) -> None:
        """Decode/verify span on the engine track + modeled KV-ring lanes
        for the batched paged-attention read."""
        t1 = self.obs.now_us()
        self.obs.trace.complete(
            name, t0, t1 - t0, pid=PID_SERVING, tid=TID_ENGINE, cat="phase",
            args={"lanes": len(lanes), "tokens": tokens})
        self._kv_lane_events(t0, t1, lanes)

    def step(self) -> bool:
        """One engine step: at most one prefill chunk + one batched decode
        call over every decode-phase lane."""
        obs = self.obs
        step_t0 = obs.now_us() if obs.enabled else 0.0
        plan = self.scheduler.schedule()
        if plan is None:
            if self.scheduler.pending:
                raise RuntimeError(
                    "paged pool cannot back even the oldest request "
                    f"({self.kv.cfg.num_blocks} blocks of {self.block_size}); "
                    "raise ServeConfig.num_blocks")
            return False
        # copy-on-write forks queued by admission: copy pool rows before
        # any write this step
        self._apply_pending_copies()
        prefill_tokens = decode_tokens = 0
        verify_tokens = drafted_tokens = accepted_tokens = 0
        read_tokens = 0
        # per-call attention-read accounting: the gather path materializes
        # every participant's full (MB*bs) logical sequence in HBM; the
        # streaming kernel only moves each participant's LIVE blocks through
        # VMEM (unmapped/released entries re-read the hot null block).
        attn_bytes_gather = attn_bytes_stream = 0
        mb_rows = self.kv.cfg.max_blocks_per_seq * self.block_size

        def _stream_bytes(lane: int) -> int:
            return sum(
                len(g.blocks_for(lane)) * self.block_size * gb
                for g, gb in zip(self.kv.groups, self._group_token_bytes))

        if plan.prefill:
            w = plan.prefill
            req = self.scheduler.request_at(w.lane)
            # shared blocks arrive via the tables READ-ONLY; the write span
            # must be exclusively owned (fork_block upholds this at
            # admission — assert it before every write)
            self.kv.assert_writable(w.lane, w.start_pos,
                                    w.start_pos + len(w.tokens))
            t0 = obs.now_us() if obs.enabled else 0.0
            logits, self.caches = self._prefill(
                self.params, self.caches,
                jnp.asarray(w.tokens[None]),
                self._tables_jnp(w.lane),
                w.start_pos, w.last_idx)
            if obs.enabled:
                self._trace_prefill(w, req, t0)
            prefill_tokens = len(w.tokens)
            read_tokens += w.start_pos + len(w.tokens)
            attn_bytes_gather += mb_rows * self._kv_token_bytes
            attn_bytes_stream += _stream_bytes(w.lane)
            if self._reclaims and w.real_tokens:
                self.kv.release_expired(
                    w.lane, w.start_pos + w.real_tokens - 1)
            if w.final:
                tok = self._sample(logits[0], req)
                req.produced.append(tok)
                obs.requests.on_first_token(req.rid)
                # the lane's full context KV is now written: publish it for
                # sharing while the lane keeps decoding
                self._prefix_insert(w.lane, req.context)
                self.scheduler.to_decode(w.lane)
                self._maybe_finish(w.lane, tok)

        if plan.decode_lanes:
            slots = self.serve.slots
            toks = np.zeros((slots, 1), np.int32)
            positions = np.zeros((slots,), np.int32)
            active = np.zeros((slots,), bool)
            for lane in plan.decode_lanes:
                req = self.scheduler.request_at(lane)
                toks[lane, 0] = req.produced[-1]
                positions[lane] = req.decode_pos
                active[lane] = True
                read_tokens += req.decode_pos + 1
                self.kv.assert_writable(lane, req.decode_pos,
                                        req.decode_pos + 1)
            t0 = obs.now_us() if obs.enabled else 0.0
            logits, self.caches = self._decode(
                self.params, self.caches, jnp.asarray(toks),
                self._tables_jnp(), jnp.asarray(positions),
                jnp.asarray(active))
            attn_bytes_gather += slots * mb_rows * self._kv_token_bytes
            attn_bytes_stream += sum(_stream_bytes(l) for l in range(slots))
            logits_np = np.asarray(logits, np.float32)
            if obs.enabled:
                self._trace_batched("decode", plan.decode_lanes, t0,
                                    len(plan.decode_lanes))
            for lane in plan.decode_lanes:
                req = self.scheduler.request_at(lane)
                req.decode_pos += 1
                tok = self._sample(logits_np[lane, 0], req)
                req.produced.append(tok)
                if self._reclaims:
                    self.kv.release_expired(lane, req.decode_pos)
                self._maybe_finish(lane, tok)
            decode_tokens = len(plan.decode_lanes)

        if plan.verify:
            v = plan.verify
            slots = self.serve.slots
            S = self.draft_len + 1
            toks = np.zeros((slots, S), np.int32)
            positions = np.zeros((slots,), np.int32)
            active = np.zeros((slots,), bool)
            nvalid = np.zeros((slots,), np.int32)
            for lane, draft in zip(v.lanes, v.drafts):
                req = self.scheduler.request_at(lane)
                toks[lane, 0] = req.produced[-1]
                toks[lane, 1 : 1 + len(draft)] = draft
                positions[lane] = req.decode_pos
                active[lane] = True
                nvalid[lane] = 1 + len(draft)
                read_tokens += req.decode_pos + 1 + len(draft)
                # the whole write span [decode_pos, decode_pos+1+len(draft))
                # must be exclusively owned: shared prefix blocks all sit
                # below decode_pos (tail forked at admission) and draft
                # blocks were freshly ensured — assert, never mutate shares
                self.kv.assert_writable(lane, req.decode_pos,
                                        req.decode_pos + 1 + len(draft))
            t0 = obs.now_us() if obs.enabled else 0.0
            logits, self.caches = self._verify(
                self.params, self.caches, jnp.asarray(toks),
                self._tables_jnp(), jnp.asarray(positions),
                jnp.asarray(active), jnp.asarray(nvalid))
            attn_bytes_gather += slots * mb_rows * self._kv_token_bytes
            attn_bytes_stream += sum(_stream_bytes(l) for l in range(slots))
            logits_np = np.asarray(logits, np.float32)
            if obs.enabled:
                self._trace_batched("verify", v.lanes, t0,
                                    int(np.sum(nvalid)))
            for lane, draft in zip(v.lanes, v.drafts):
                req = self.scheduler.request_at(lane)
                nd = len(draft)
                drafted_tokens += nd
                verify_tokens += nd + 1
                # greedy-exact acceptance: every emitted token is sampled
                # from the TARGET logits at its logical token index (the
                # same key plain decode would use), so the stream is
                # token-for-token identical with speculation off; draft
                # d_{i+1} survives only if it EQUALS the sampled token
                tok = -1
                for i in range(nd + 1):
                    tok = self._sample(logits_np[lane, i], req)
                    req.decode_pos += 1
                    req.produced.append(tok)
                    done = req.remaining <= 0 or (
                        self.serve.eos_token is not None
                        and tok == self.serve.eos_token)
                    matched = i < nd and tok == draft[i]
                    if matched:
                        accepted_tokens += 1
                    if done or not matched:
                        break
                # rollback: drop table entries mapped past the accepted
                # point (blocks ensured for rejected drafts go back to the
                # pool); stale rows inside kept blocks are masked/overwritten
                self.kv.truncate_blocks(
                    lane, -(-req.decode_pos // self.block_size))
                if self._reclaims:
                    self.kv.release_expired(lane, req.decode_pos)
                self._maybe_finish(lane, tok)

        tokens = prefill_tokens + decode_tokens + verify_tokens
        # one typed ledger row per step (schema: obs.ledger.STEP_SCHEMA).
        # The ledger derives hbm_bytes = param_bytes + kv_write_bytes +
        # kv_read_bytes — the same projection the engine used to hand-build:
        # weights stream once per step, every processed token writes its KV,
        # reads cover each participant's live prefix.  attn_bytes_gather is
        # the bytes `_paged_gather` would MATERIALIZE (every participant's
        # full MB*bs logical sequence per layer); attn_bytes_stream is what
        # the Pallas kernel DMAs (each participant's LIVE blocks per group).
        row = self.metrics.record(
            tokens=tokens,
            prefill_tokens=prefill_tokens,
            # non-pad prompt tokens in the chunk (<= prefill_tokens; the
            # padded count is the flatness/traffic quantity)
            prefill_real_tokens=(plan.prefill.real_tokens
                                 if plan.prefill else 0),
            decode_tokens=decode_tokens,
            verify_tokens=verify_tokens,
            drafted_tokens=drafted_tokens,
            accepted_tokens=accepted_tokens,
            blocks_in_use=self.kv.blocks_in_use,
            free_blocks=self.kv.num_free,
            queue_depth=self.scheduler.queue_depth,
            preempted=len(plan.preempted),
            prefix_hit_tokens=plan.prefix_hit_tokens,
            blocks_shared=self.kv.blocks_shared,
            param_bytes=self._param_bytes,
            kv_write_bytes=tokens * self._kv_token_bytes,
            kv_read_bytes=read_tokens * self._kv_token_bytes,
            # KV the radix index served this step: re-prefill bytes that
            # never crossed HBM
            prefix_saved_bytes=plan.prefix_hit_tokens * self._kv_token_bytes,
            attn_bytes_gather=attn_bytes_gather,
            attn_bytes_stream=attn_bytes_stream,
            step_wall_us=(obs.now_us() - step_t0) if obs.enabled else 0.0,
        )
        if obs.enabled:
            obs.trace.complete(
                "step", step_t0, row["step_wall_us"], pid=PID_SERVING,
                tid=TID_ENGINE, cat="step",
                args={"step": row["step"], "tokens": tokens,
                      "hbm_bytes": row["hbm_bytes"]})
            obs.trace.counter(
                "hbm bytes/step",
                {"total": row["hbm_bytes"], "stream": attn_bytes_stream},
                ts_us=step_t0, pid=PID_SERVING)
        return True

    def defragment(self) -> None:
        """Compact each group's physical pool (gathers then touch one dense
        prefix); pools are permuted in lockstep with the tables, and every
        holder of a moved shared block — other lanes' tables AND the prefix
        index — is rewritten through the same old->new map."""
        self._apply_pending_copies()      # copies reference pre-perm ids
        perms = self.kv.defragment()
        jperms = tuple(jnp.asarray(p) for p in perms)

        def apply(path, pool):
            jperm = jperms[tf.cache_path_group(self.cfg, path)]
            return (pool[:, jperm] if tf.is_stacked_cache_path(path)
                    else pool[jperm])

        self.caches = jax.tree_util.tree_map_with_path(apply, self.caches)
        if self.prefix is not None:
            self.prefix.remap(tuple(PagedKVCache.old_to_new(p)
                                    for p in perms))

    def run(self, max_steps: int = 10_000):
        steps = 0
        while self.pending and steps < max_steps:
            self.step()
            steps += 1
        return self._results


def make_engine(cfg: ModelConfig, params: Pytree, serve: ServeConfig,
                draft_model: "tuple[ModelConfig, Pytree] | None" = None):
    """Paged engine when the architecture supports it, dense-cache fallback
    (recurrent/cross blocks — no speculation there) otherwise."""
    if tf.supports_paged(cfg if serve.dense_kernel is None
                         else cfg.with_(dense_kernel=serve.dense_kernel)):
        return ServingEngine(cfg, params, serve, draft_model=draft_model)
    from repro.serving.dense_engine import DenseServingEngine
    return DenseServingEngine(cfg, params, serve)
