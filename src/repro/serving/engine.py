"""Batched serving engine: continuous-batching decode over a fixed slot pool.

A request = prompt tokens + max_new_tokens.  The engine keeps `slots` decode
lanes; finished lanes are refilled from the queue (continuous batching) by
re-running prefill for the incoming prompt into the lane's cache slice.
Per-lane `pos` drives the causal masks, so lanes at different generation
depths coexist in one batched decode_step — the serving analogue of the
paper's point: keep every "macro" (lane) busy instead of barriering on the
slowest.

Decode is greedy (argmax) by default with optional temperature sampling.
All steps are jit-compiled once per (slots, max_len) shape.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tf

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    slots: int = 4                 # concurrent decode lanes
    max_len: int = 256             # cache capacity per lane
    temperature: float = 0.0       # 0 => greedy
    eos_token: int | None = None
    dense_kernel: str | None = None  # override cfg.dense_kernel at serve time;
                                     # threads through prefill AND decode, so
                                     # "kernel" streams every projection (attn
                                     # q/k/v/o, MLA, MoE experts, SSM/xLSTM)
                                     # through the GPP Pallas matmul instead
                                     # of the reference path at large shapes


@dataclasses.dataclass
class _Lane:
    request_id: int | None = None
    pos: int = 0
    remaining: int = 0
    tokens: list = dataclasses.field(default_factory=list)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: Pytree, serve: ServeConfig):
        if serve.dense_kernel is not None:
            cfg = cfg.with_(dense_kernel=serve.dense_kernel)
        self.cfg = cfg
        self.params = params
        self.serve = serve
        self.lanes = [_Lane() for _ in range(serve.slots)]
        self._queue: list[tuple[int, np.ndarray, int]] = []
        self._results: dict[int, list[int]] = {}
        self._next_id = 0

        def _prefill_one(params, tokens):
            batch = {"tokens": tokens}
            return tf.prefill(params, cfg, batch, max_len=serve.max_len)

        def _decode(params, toks, caches, pos_scalar):
            return tf.decode_step(params, cfg, toks, caches, pos_scalar)

        self._prefill = jax.jit(_prefill_one)
        self._decode = jax.jit(_decode)
        self.caches = None

    # ---------------------------------------------------------------- API
    def submit(self, prompt: list[int], max_new_tokens: int = 32) -> int:
        rid = self._next_id
        self._next_id += 1
        self._queue.append((rid, np.asarray(prompt, np.int32), max_new_tokens))
        return rid

    def result(self, rid: int) -> list[int] | None:
        return self._results.get(rid)

    @property
    def pending(self) -> int:
        return len(self._queue) + sum(1 for l in self.lanes if l.request_id is not None)

    # ------------------------------------------------------------ engine
    def _admit(self):
        """Fill idle lanes from the queue (continuous batching)."""
        for i, lane in enumerate(self.lanes):
            if lane.request_id is not None or not self._queue:
                continue
            rid, prompt, max_new = self._queue.pop(0)
            logits, caches = self._prefill(self.params, prompt[None, :])
            first = int(jnp.argmax(logits[0, -1]))
            # batch dim is 1 for stacked ("blocks") cache leaves, 0 otherwise
            def bdim(path):
                return 1 if any(getattr(k, "key", None) == "blocks"
                                for k in path) else 0
            if self.caches is None:
                # materialize an empty slot-pool cache from this prototype
                def pool(path, c):
                    d = bdim(path)
                    shape = list(c.shape)
                    shape[d] = self.serve.slots
                    return jnp.zeros(shape, c.dtype)
                self.caches = jax.tree_util.tree_map_with_path(pool, caches)
            # write this lane's cache slice
            def write(path, pool, c):
                return jax.lax.dynamic_update_slice_in_dim(pool, c, i, bdim(path))
            self.caches = jax.tree_util.tree_map_with_path(
                write, self.caches, caches)
            lane.request_id = rid
            lane.pos = len(prompt)
            lane.remaining = max_new - 1
            lane.tokens = [first]

    def step(self):
        """One batched decode step across all active lanes."""
        self._admit()
        active = [l for l in self.lanes if l.request_id is not None]
        if not active:
            return False
        toks = np.zeros((self.serve.slots, 1), np.int32)
        for i, lane in enumerate(self.lanes):
            if lane.request_id is not None and lane.tokens:
                toks[i, 0] = lane.tokens[-1]
        # single shared pos isn't valid for heterogeneous lanes; decode per
        # max pos is conservative — we run one step per unique pos group.
        # (simple and correct; production would use per-lane position vectors)
        pos_groups: dict[int, list[int]] = {}
        for i, lane in enumerate(self.lanes):
            if lane.request_id is not None:
                pos_groups.setdefault(lane.pos, []).append(i)
        for pos, lanes_at in pos_groups.items():
            logits, self.caches = self._decode(
                self.params, jnp.asarray(toks), self.caches, pos)
            for i in lanes_at:
                lane = self.lanes[i]
                nxt = int(jnp.argmax(logits[i, -1]))
                lane.tokens.append(nxt)
                lane.pos += 1
                lane.remaining -= 1
                done = lane.remaining <= 0 or (
                    self.serve.eos_token is not None and nxt == self.serve.eos_token)
                if done:
                    self._results[lane.request_id] = lane.tokens
                    self.lanes[i] = _Lane()
        return True

    def run(self, max_steps: int = 10_000):
        steps = 0
        while self.pending and steps < max_steps:
            self.step()
            steps += 1
        return self._results
