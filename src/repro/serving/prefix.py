"""Radix-tree shared-prefix KV index over the paged block pools.

The generalized ping-pong lens: the scarce serving resource is off-chip
bytes per step, and the single largest source of REDUNDANT bytes is
re-prefilling identical prompt prefixes — every re-prefilled token re-reads
the full weight stream and re-writes its KV.  This index makes previously
computed prefix KV addressable by token content, so an admitted request maps
the matched blocks straight into its table and prefills only the novel
suffix: the bytes that must move are the ones that carry new information.

Structure
---------
A radix tree (path-compressed trie) over token sequences at KV-BLOCK
granularity: each edge/node covers a whole number of `block_size`-token
blocks, child edges are keyed by their first block's token tuple (two
sequences diverging INSIDE a block get sibling edges — blocks are the unit
of sharing, so a mid-block split has nothing to share).  A node owns, per
LAYER GROUP (`GroupedPagedCache`), one physical block id per covered block;
id 0 marks expired sliding-window coverage (reads land on the masked null
block).  A leaf may additionally carry a partially-filled TAIL block — the
last `k < block_size` tokens of an inserted sequence — which a matching
request adopts via copy-on-write (`fork_block`): the fork copies the block,
the new lane overwrites rows past the matched point, and nobody aliases.

Ownership
---------
Every non-null block a node references holds exactly one prefix-index
reference (`PagedKVCache.index_acquire`); lanes mapping the same block hold
their own references.  Blocks therefore survive the lanes that computed
them and return to the allocator only when evicted here AND unmapped
everywhere.  Eviction is LRU over ZERO-LANE-REF leaves (blocks held by the
index alone), wired into the scheduler's block-pressure path ahead of
preemption: cold cached prefixes are reclaimed before any running request
loses its KV.

Correctness at the window boundary
----------------------------------
A match of C tokens is only usable if every key position a future query can
still see is backed: for a layer group with sliding window W, positions
[C - W + 1, C) must map non-null blocks (older nulls are invisible to every
query at position >= C and harmless); for a global group any null coverage
ends the match.  `match` enforces both, and additionally caps C so the
request's padded prefill extent still fits the block table.

Pure host-side bookkeeping (numpy token compares + python dicts); device
pool copies for COW forks ride the cache's `pending_copies` queue.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.schedule import round_up
from repro.serving.cache import GroupedPagedCache


@dataclasses.dataclass(frozen=True)
class PrefixHit:
    """One index probe result.

    tokens   matched token count C (0 = miss).  The caller skips prefilling
             [0, C) entirely; C is capped at len(query) - 1 so at least one
             token is computed to produce logits.
    blocks   per-group physical ids for the C // block_size fully-matched
             blocks (0 entries = expired window coverage, reads masked).
    tail     per-group physical ids of the partially-matched block backing
             tokens [C // bs * bs, C) when C is not block-aligned — the
             caller must map it copy-on-write (fork) before appending.
    """

    tokens: int
    blocks: "tuple[tuple[int, ...], ...]"
    tail: "tuple[int, ...] | None" = None


_MISS = PrefixHit(0, ())

_NO_DRAFT = np.zeros((0,), np.int32)


def ngram_propose(tokens, k: int, *, max_ngram: int = 3,
                  min_ngram: int = 1) -> np.ndarray:
    """Prompt-lookup self-drafting: find the LATEST earlier occurrence of
    the sequence's own trailing n-gram and propose the up-to-`k` tokens
    that followed it (longest n tried first).  Pure token statistics — no
    weights are streamed to produce the draft, so every accepted token is
    a free amortization of the verify pass's weight read (the GPP
    bytes-per-useful-token argument).  Returns (d,) int32 with
    0 <= d <= k; empty = no draftable repetition."""
    toks = np.asarray(tokens, np.int32)
    L = int(toks.shape[0])
    if k < 1 or L < min_ngram + 1:
        return _NO_DRAFT
    for n in range(min(max_ngram, L - 1), min_ngram - 1, -1):
        pattern = toks[L - n:]
        # windows over toks[:L-1]: every occurrence has a continuation,
        # and the trailing n-gram itself (start L-n) is excluded
        windows = np.lib.stride_tricks.sliding_window_view(toks[: L - 1], n)
        hits = np.nonzero((windows == pattern).all(axis=1))[0]
        if len(hits):
            i = int(hits[-1])             # last occurrence: recency wins
            return toks[i + n : i + n + k].copy()
    return _NO_DRAFT


class _Node:
    __slots__ = ("tokens", "blocks", "tail_tokens", "tail_blocks",
                 "children", "parent", "last_used")

    def __init__(self, tokens: np.ndarray, blocks: "list[list[int]]",
                 parent: "_Node | None"):
        self.tokens = tokens              # (n*bs,) int32 — full blocks only
        self.blocks = blocks              # per-group, len n each
        self.tail_tokens: "np.ndarray | None" = None   # (k,), 1 <= k < bs
        self.tail_blocks: "list[int] | None" = None    # per-group
        self.children: "dict[tuple, _Node]" = {}
        self.parent = parent
        self.last_used = 0

    @property
    def nblocks(self) -> int:
        return len(self.blocks[0]) if self.blocks else 0


def _block_key(tokens: np.ndarray, off_blk: int, bs: int) -> tuple:
    return tuple(int(t) for t in tokens[off_blk * bs : (off_blk + 1) * bs])


class PrefixCache:
    """Radix-tree prefix index over a `GroupedPagedCache` (module docstring).

    max_blocks  cap on block references the index may hold (0 = unbounded);
                LRU leaves are evicted past it, and under pool pressure the
                scheduler calls `evict` regardless of the cap.
    """

    def __init__(self, cache: GroupedPagedCache, *, max_blocks: int = 0):
        self.cache = cache
        self.bs = cache.cfg.block_size
        self.G = cache.num_groups
        self.max_blocks = max_blocks
        self.root = _Node(np.zeros((0,), np.int32),
                          [[] for _ in range(self.G)], None)
        self._tick = 0
        self.blocks_held = 0
        # stats
        self.lookups = 0
        self.hits = 0
        self.hit_tokens = 0
        self.inserted_blocks = 0
        self.evicted_blocks = 0

    # ------------------------------------------------------------- helpers
    def _touch(self, node: "_Node") -> None:
        self._tick += 1
        while node is not None:
            node.last_used = self._tick
            node = node.parent

    def _acquire(self, gi: int, block: int) -> None:
        self.cache.groups[gi].index_acquire(block)
        self.blocks_held += 1
        self.inserted_blocks += 1

    def _release(self, gi: int, block: int) -> int:
        freed = self.cache.groups[gi].index_release(block)
        self.blocks_held -= 1
        return freed

    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    # --------------------------------------------------------------- match
    def match(self, tokens: np.ndarray, *, max_len: "int | None" = None,
              chunk: "int | None" = None) -> PrefixHit:
        """Longest reusable prefix of `tokens` (see module docstring for the
        window-coverage and at-least-one-computed-token caps).

        max_len / chunk: when given, C is further capped so the remaining
        context padded to chunk multiples — C + round_up(len - C, chunk) —
        fits a max_len-token block table (the prefill extent the scheduler
        will actually drive)."""
        bs = self.bs
        L = int(len(tokens))
        self.lookups += 1
        cap = L - 1
        if cap < 1:
            return _MISS

        blocks: "list[list[int]]" = [[] for _ in range(self.G)]
        null_flags: "list[list[bool]]" = [[] for _ in range(self.G)]
        node = self.root
        off = 0                       # fully matched blocks
        at_edge = True                # standing at a node boundary?
        div_j = 0                     # in-node stop index when not at_edge
        while (off + 1) * bs <= cap:
            child = node.children.get(_block_key(tokens, off, bs))
            if child is None:
                break
            j, cn = 0, child.nblocks
            stop_global = False
            while j < cn and (off + 1) * bs <= cap and np.array_equal(
                    child.tokens[j * bs : (j + 1) * bs],
                    tokens[off * bs : (off + 1) * bs]):
                ids = [child.blocks[gi][j] for gi in range(self.G)]
                if any(b == 0 and self.cache.horizons[gi] is None
                       for gi, b in enumerate(ids)):
                    stop_global = True   # global reach cannot tolerate holes
                    break
                for gi, b in enumerate(ids):
                    blocks[gi].append(b)
                    null_flags[gi].append(b == 0)
                off += 1
                j += 1
            self._touch(child)
            node = child
            at_edge = j == cn
            div_j = j
            if not at_edge or stop_global:
                break

        # partially-matching block at the stop point — the copy-on-write
        # candidate.  Sources: a stored partial tail, the first block of any
        # child whose tokens diverge mid-block, or the in-node block where
        # the walk stopped (divergence or the computed-token cap).  Whatever
        # matches the most leading tokens wins; the fork's stale rows past
        # the match are overwritten by the lane's own prefill.
        k2 = 0
        tail: "tuple[int, ...] | None" = None
        limit = cap - off * bs

        def consider(block_tokens, ids) -> None:
            nonlocal k2, tail
            if not all(ids):
                return               # cannot fork a null source block
            kk, lim = 0, min(len(block_tokens), limit)
            while kk < lim and int(block_tokens[kk]) == int(
                    tokens[off * bs + kk]):
                kk += 1
            if kk > k2:
                k2, tail = kk, tuple(ids)

        if limit > 0:
            if not at_edge:
                consider(node.tokens[div_j * bs : (div_j + 1) * bs],
                         [node.blocks[gi][div_j] for gi in range(self.G)])
            else:
                if node.tail_tokens is not None:
                    consider(node.tail_tokens, node.tail_blocks)
                for ch in node.children.values():
                    consider(ch.tokens[:bs],
                             [ch.blocks[gi][0] for gi in range(self.G)])

        C = off * bs + k2

        # cap to the block-table extent the scheduler will drive
        if max_len is not None and chunk is not None:
            while C and C + round_up(L - C, chunk) > max_len:
                C -= 1

        def build(C: int) -> PrefixHit:
            if C <= 0:
                return _MISS
            nfull, k = divmod(C, bs)
            t: "tuple[int, ...] | None" = None
            if k:
                if nfull < off:
                    # C was capped into the fully-matched region: fork the
                    # full block covering [nfull*bs, C) — its first k rows
                    # match, the rest is overwritten after the fork.
                    ids = [blocks[gi][nfull] for gi in range(self.G)]
                    if not all(ids):
                        return build(nfull * bs)   # can't fork a null block
                    t = tuple(ids)
                elif tail is not None and k <= k2:
                    t = tail
                else:
                    return build(nfull * bs)
            # window feasibility: every group with horizon W needs non-null
            # coverage of [C - W + 1, C)
            for gi, W in enumerate(self.cache.horizons):
                if W is None or C == 0:
                    continue
                nulls = null_flags[gi][:nfull + (1 if k else 0)]
                null_end = 0
                for j, isnull in enumerate(nulls):
                    if isnull:
                        null_end = (j + 1) * bs
                if null_end > max(0, C - (W - 1)):
                    return _MISS        # holes inside the live window
            return PrefixHit(
                C, tuple(tuple(blocks[gi][:nfull]) for gi in range(self.G)),
                t)

        hit = build(C)
        if hit.tokens:
            self.hits += 1
            self.hit_tokens += hit.tokens
        return hit

    # -------------------------------------------------------------- insert
    def insert(self, tokens: np.ndarray,
               blocks_by_group: "tuple[list[int], ...]") -> int:
        """Index `tokens` (every position's KV must be written) backed by
        the given per-group physical blocks (len ceil(len(tokens)/bs) each;
        0 entries = expired window coverage).

        Walks the tree adopting only NOVEL suffix blocks (+1 index ref
        each); spans already present keep the tree's canonical blocks, and a
        real block UPGRADES a null entry left by an earlier window-expired
        insert.  Returns the number of block references adopted.
        """
        bs = self.bs
        L = int(len(tokens))
        if L == 0:
            return 0
        nfull, k = divmod(L, bs)
        if any(len(b) != nfull + (1 if k else 0) for b in blocks_by_group) \
                or len(blocks_by_group) != self.G:
            raise ValueError(
                f"need {self.G} block lists of {nfull + (1 if k else 0)} "
                f"entries for {L} tokens")
        adopted = 0
        node = self.root
        off = 0
        while off < nfull:
            key = _block_key(tokens, off, bs)
            child = node.children.get(key)
            if child is None:
                adopted += self._add_child(node, tokens[off * bs : nfull * bs],
                                           [list(b[off:nfull])
                                            for b in blocks_by_group])
                off = nfull
                break
            m, cn = 1, child.nblocks             # key matched => block 0 does
            while m < cn and off + m < nfull and np.array_equal(
                    child.tokens[m * bs : (m + 1) * bs],
                    tokens[(off + m) * bs : (off + m + 1) * bs]):
                m += 1
            for gi in range(self.G):             # upgrade null coverage
                for j in range(m):
                    if child.blocks[gi][j] == 0 and blocks_by_group[gi][off + j]:
                        child.blocks[gi][j] = blocks_by_group[gi][off + j]
                        self._acquire(gi, child.blocks[gi][j])
                        adopted += 1
            if m < cn:
                child = self._split(child, m)
            self._touch(child)
            node = child
            off += m
        # locate the node ending exactly at block nfull for tail attachment
        target = self._descend_exact(tokens, nfull)
        if target is None:
            return adopted
        if k:
            adopted += self._attach_tail(
                target, tokens[nfull * bs :],
                [b[nfull] for b in blocks_by_group])
        self._touch(target)
        self.enforce_cap()
        return adopted

    def _add_child(self, node: "_Node", tokens: np.ndarray,
                   blocks: "list[list[int]]") -> int:
        """Create a child of `node` covering `tokens`, adopting its blocks.
        Drops a tail on `node` that aliases the child's first block (a
        re-insert of the same lane's now-full former tail).  Returns refs
        adopted."""
        if node.tail_blocks is not None and any(
                t and t == blocks[gi][0]
                for gi, t in enumerate(node.tail_blocks)):
            self._drop_tail(node)
        child = _Node(np.asarray(tokens, np.int32), blocks, node)
        adopted = 0
        for gi in range(self.G):
            for b in blocks[gi]:
                if b:
                    self._acquire(gi, b)
                    adopted += 1
        node.children[_block_key(tokens, 0, self.bs)] = child
        self._touch(child)
        return adopted

    def _split(self, child: "_Node", m: int) -> "_Node":
        """Split `child` at block boundary m: a new upper node keeps blocks
        [0, m); `child` keeps the rest (and its tail) underneath it.
        Returns the upper node."""
        bs = self.bs
        parent = child.parent
        upper = _Node(child.tokens[: m * bs],
                      [b[:m] for b in child.blocks], parent)
        upper.last_used = child.last_used
        parent.children[_block_key(upper.tokens, 0, bs)] = upper
        child.tokens = child.tokens[m * bs :]
        child.blocks = [b[m:] for b in child.blocks]
        child.parent = upper
        upper.children[_block_key(child.tokens, 0, bs)] = child
        return upper

    def _descend_exact(self, tokens: np.ndarray, nfull: int) -> "_Node | None":
        """The node whose covered span ends exactly at block `nfull` on the
        path spelled by `tokens` (root for nfull == 0)."""
        bs = self.bs
        node, off = self.root, 0
        while off < nfull:
            child = node.children.get(_block_key(tokens, off, bs))
            if child is None or off + child.nblocks > nfull:
                return None
            node = child
            off += child.nblocks
        return node

    def _attach_tail(self, node: "_Node", tail_tokens: np.ndarray,
                     tail_blocks: "list[int]") -> int:
        """Adopt a partial tail block at `node`.  Keep-longest policy: an
        existing tail survives unless the new one strictly extends it.  A
        tail is only useful if every group's block is real (forking needs
        source rows)."""
        if not all(tail_blocks):
            return 0
        if node.tail_tokens is not None:
            old = node.tail_tokens
            if not (len(tail_tokens) > len(old)
                    and np.array_equal(old, tail_tokens[: len(old)])):
                return 0
            self._drop_tail(node)
        # a child keyed by this span's block may already own the same
        # physical block (full-block re-insert arrived first): skip
        for child in node.children.values():
            if any(child.blocks[gi][0] == b
                   for gi, b in enumerate(tail_blocks) if b):
                return 0
        node.tail_tokens = np.asarray(tail_tokens, np.int32)
        node.tail_blocks = list(tail_blocks)
        for gi, b in enumerate(tail_blocks):
            self._acquire(gi, b)
        return len(tail_blocks)

    def _drop_tail(self, node: "_Node") -> int:
        freed = 0
        if node.tail_blocks is not None:
            for gi, b in enumerate(node.tail_blocks):
                if b:
                    freed += self._release(gi, b)
                    self.evicted_blocks += 1
        node.tail_tokens = None
        node.tail_blocks = None
        return freed

    # ------------------------------------------------------------ eviction
    def _leaves(self) -> "list[_Node]":
        out, stack = [], [self.root]
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if n is not self.root and not n.children:
                out.append(n)
        return out

    def _evictable(self, node: "_Node") -> bool:
        """Zero-lane-ref: every real block is held by the index alone."""
        ids = [(gi, b) for gi in range(self.G) for b in node.blocks[gi] if b]
        if node.tail_blocks is not None:
            ids += [(gi, b) for gi, b in enumerate(node.tail_blocks) if b]
        return all(self.cache.groups[gi].ref_count[b] == 1 for gi, b in ids)

    def _release_node(self, node: "_Node") -> int:
        freed = self._drop_tail(node)
        for gi in range(self.G):
            for b in node.blocks[gi]:
                if b:
                    freed += self._release(gi, b)
                    self.evicted_blocks += 1
        parent = node.parent
        for key, ch in list(parent.children.items()):
            if ch is node:
                del parent.children[key]
        return freed

    def evict(self, min_blocks: int = 1) -> int:
        """LRU-evict zero-lane-ref leaves until at least `min_blocks` went
        back to the allocator (or nothing evictable remains).  Returns the
        number of blocks actually freed — the scheduler's block-pressure
        path calls this BEFORE preempting a running request."""
        freed = 0
        while freed < min_blocks:
            cands = [n for n in self._leaves() if self._evictable(n)]
            if not cands:
                # tails on interior nodes are individually reclaimable
                for n in self._walk():
                    if n.tail_blocks is not None and all(
                            self.cache.groups[gi].ref_count[b] == 1
                            for gi, b in enumerate(n.tail_blocks) if b):
                        freed += self._drop_tail(n)
                        if freed >= min_blocks:
                            return freed
                break
            victim = min(cands, key=lambda n: n.last_used)
            freed += self._release_node(victim)
        return freed

    def enforce_cap(self) -> None:
        """Evict LRU leaves down to `max_blocks` held references.  Called
        after every insert, and again by the engine whenever a lane is
        freed — blocks still mapped by a running lane are not evictable, so
        the cap can only take hold once the lane lets go."""
        while self.max_blocks and self.blocks_held > self.max_blocks:
            before = self.blocks_held
            self.evict(1)
            if self.blocks_held >= before:   # nothing evictable
                break

    # ----------------------------------------------------------- remapping
    def _walk(self) -> "list[_Node]":
        out, stack = [], [self.root]
        while stack:
            n = stack.pop()
            out.append(n)
            stack.extend(n.children.values())
        return out

    def remap(self, old_to_new_by_group: "tuple[np.ndarray, ...]") -> None:
        """Rewrite every referenced block id after a pool defragmentation —
        MUST be called with `PagedKVCache.old_to_new(perm)` for each group
        whenever the cache defragments, or the index dangles."""
        for node in self._walk():
            for gi, o2n in enumerate(old_to_new_by_group):
                node.blocks[gi] = [int(o2n[b]) if b else 0
                                   for b in node.blocks[gi]]
                if node.tail_blocks is not None and node.tail_blocks[gi]:
                    node.tail_blocks[gi] = int(o2n[node.tail_blocks[gi]])

    # ------------------------------------------------------------ drafting
    def _stored_sequences(self) -> "list[np.ndarray]":
        """Token sequences stored in the tree — one per leaf or
        tail-carrying node, reconstructed root-to-node by ascending
        parents — most recently used first.  These are the token streams
        the index has KV for; as a side effect of being a radix tree over
        past traffic they double as the cross-request corpus for
        prompt-lookup drafting (`suffix_lookup`)."""
        seqs: "list[tuple[int, np.ndarray]]" = []
        for node in self._walk():
            if node is self.root and node.tail_tokens is None:
                continue
            if node.children and node.tail_tokens is None:
                continue                   # interior span: a longer stored
                #                            sequence covers it already
            parts = []
            n = node
            while n is not None:
                parts.append(n.tokens)
                n = n.parent
            toks = np.concatenate(parts[::-1])
            if node.tail_tokens is not None:
                toks = np.concatenate([toks, node.tail_tokens])
            if len(toks):
                seqs.append((node.last_used, toks))
        seqs.sort(key=lambda t: -t[0])
        return [t for _, t in seqs]

    def suffix_lookup(self, context, k: int, *, max_ngram: int = 3,
                      min_ngram: int = 1) -> np.ndarray:
        """Cross-request prompt-lookup: search the stored sequences for the
        trailing n-gram of `context` and return the up-to-`k` tokens that
        followed it (longest n first; most-recently-used sequence first;
        within a sequence the last occurrence wins).  Complements
        `ngram_propose`'s lane-local search when the repetition lives in
        ANOTHER request's history (multi-turn traffic).  Returns (d,)
        int32, empty on no match."""
        ctx = np.asarray(context, np.int32)
        L = int(ctx.shape[0])
        if k < 1 or L < min_ngram:
            return _NO_DRAFT
        for n in range(min(max_ngram, L), min_ngram - 1, -1):
            pattern = ctx[L - n:]
            for seq in self._stored_sequences():
                if len(seq) <= n:
                    continue
                windows = np.lib.stride_tricks.sliding_window_view(
                    seq[: len(seq) - 1], n)
                hits = np.nonzero((windows == pattern).all(axis=1))[0]
                if len(hits):
                    i = int(hits[-1])
                    return seq[i + n : i + n + k].astype(np.int32).copy()
        return _NO_DRAFT

    # ----------------------------------------------------------- test hooks
    def held_blocks(self) -> "tuple[dict[int, int], ...]":
        """Per-group {block id: refs held by the index} (each 1 by
        invariant) — cross-checked by `PagedKVCache.check_invariants`."""
        held: "tuple[dict[int, int], ...]" = tuple({} for _ in range(self.G))
        for node in self._walk():
            for gi in range(self.G):
                for b in node.blocks[gi]:
                    if b:
                        held[gi][b] = held[gi].get(b, 0) + 1
                if node.tail_blocks is not None and node.tail_blocks[gi]:
                    b = node.tail_blocks[gi]
                    held[gi][b] = held[gi].get(b, 0) + 1
        return held
