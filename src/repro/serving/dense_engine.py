"""Dense-cache serving engine (the pre-paged seed design), kept as

  * the fallback for recurrent architectures (mamba/xlstm blocks carry O(1)
    state, not paged KV — chunked prefill of padded prompts would push pad
    tokens through their state update);
  * the baseline the paged engine's parity tests and BENCH_serving.json
    benchmarks compare against.

Design (seed): `slots` decode lanes over a dense `(slots, max_len)` cache;
finished lanes are refilled from the queue by re-running whole-prompt
prefill (one jit trace PER DISTINCT PROMPT LENGTH) and decode runs one call
per distinct lane position — both the bursty anti-patterns the paged engine
(`serving.engine.ServingEngine`) removes.

Per-step token counts are recorded into `self.metrics` so the serving
benchmark can report this engine's burstiness (tokens/step CoV) next to the
paged engine's flat schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.schedule import tokens_per_step_cov
from repro.models import transformer as tf
from repro.obs import make_telemetry
from repro.obs.ledger import BandwidthLedger
from repro.obs.trace import (PID_REQUESTS, PID_SERVING, TID_ENGINE,
                             TID_LANE0, annotate_serving_tracks)
from repro.serving.engine import ServeConfig, sample_token

Pytree = Any


@dataclasses.dataclass
class _Lane:
    request_id: int | None = None
    pos: int = 0
    remaining: int = 0
    tokens: list = dataclasses.field(default_factory=list)


class DenseServingEngine:
    def __init__(self, cfg: ModelConfig, params: Pytree, serve: ServeConfig):
        if serve.dense_kernel is not None:
            cfg = cfg.with_(dense_kernel=serve.dense_kernel)
        self.cfg = cfg
        self.params = params
        self.serve = serve
        self.lanes = [_Lane() for _ in range(serve.slots)]
        self._queue: list[tuple[int, np.ndarray, int]] = []
        self._results: dict[int, list[int]] = {}
        self._next_id = 0
        # same telemetry handle + typed step ledger as the paged engine, so
        # both engines emit the one shared schema (obs.ledger.STEP_SCHEMA)
        obs_on = serve.obs if serve.obs is not None else cfg.obs
        self.obs = make_telemetry(
            obs_on,
            trace_capacity=serve.trace_capacity or cfg.obs_trace_capacity)
        annotate_serving_tracks(self.obs.trace, serve.slots)
        self.metrics = BandwidthLedger(retention=(
            serve.metrics_retention if serve.metrics_retention is not None
            else cfg.metrics_retention))
        self._param_bytes = cfg.active_params() * cfg.jdtype.itemsize
        self._kv_token_bytes = 0       # measured from the first prefill's
        #                                materialized cache (recurrent state
        #                                amortized over max_len)
        self.trace_counts = {"prefill": 0, "decode": 0}

        def _prefill_one(params, tokens):
            self.trace_counts["prefill"] += 1
            batch = {"tokens": tokens}
            return tf.prefill(params, cfg, batch, max_len=serve.max_len)

        def _decode(params, toks, caches, pos_scalar):
            self.trace_counts["decode"] += 1
            return tf.decode_step(params, cfg, toks, caches, pos_scalar)

        self._prefill = jax.jit(_prefill_one)
        self._decode = jax.jit(_decode)
        self.caches = None

    # ---------------------------------------------------------------- API
    def submit(self, prompt: list[int], max_new_tokens: int = 32) -> int:
        rid = self._next_id
        self._next_id += 1
        self._queue.append((rid, np.asarray(prompt, np.int32), max_new_tokens))
        self.obs.requests.on_submit(rid)
        if self.obs.enabled:
            self.obs.trace.async_begin(
                f"req {rid}", rid, pid=PID_REQUESTS,
                args={"prompt_tokens": len(prompt),
                      "max_new": max_new_tokens})
        return rid

    def _finish(self, rid: int, tokens: "list[int]") -> None:
        self._results[rid] = tokens
        self.obs.requests.on_finish(rid, len(tokens))
        if self.obs.enabled:
            self.obs.trace.async_end(f"req {rid}", rid, pid=PID_REQUESTS,
                                     args={"tokens": len(tokens)})

    def result(self, rid: int) -> list[int] | None:
        return self._results.get(rid)

    @property
    def pending(self) -> int:
        return len(self._queue) + sum(1 for l in self.lanes if l.request_id is not None)

    def flatness_cov(self) -> float:
        """Coefficient of variation of tokens/step (prefill bursts make the
        dense engine's high; the paged engine's is the flat comparison)."""
        return tokens_per_step_cov([m["tokens"] for m in self.metrics])

    # ------------------------------------------------------------ engine
    def _admit(self) -> int:
        """Fill idle lanes from the queue (continuous batching).  Returns
        prefill tokens processed (whole prompts — the bursty phase)."""
        prefill_tokens = 0
        for i, lane in enumerate(self.lanes):
            if lane.request_id is not None or not self._queue:
                continue
            rid, prompt, max_new = self._queue.pop(0)
            t0 = self.obs.now_us() if self.obs.enabled else 0.0
            logits, caches = self._prefill(self.params, prompt[None, :])
            if self.obs.enabled:
                self.obs.trace.complete(
                    "prefill", t0, self.obs.now_us() - t0, pid=PID_SERVING,
                    tid=TID_LANE0 + i, cat="phase",
                    args={"rid": rid, "tokens": len(prompt)})
            prefill_tokens += len(prompt)
            first = sample_token(self.serve, rid, 0, logits[0, -1])
            self.obs.requests.on_first_token(rid)
            if max_new <= 1 or (self.serve.eos_token is not None
                                and first == self.serve.eos_token):
                # finished on the prefill-sampled token: never occupies a
                # lane (matches the paged engine's _maybe_finish semantics)
                self._finish(rid, [first])
                continue
            # batch dim is 1 for stacked ("blocks") cache leaves, 0 otherwise
            def bdim(path):
                return 1 if tf.is_stacked_cache_path(path) else 0
            if self.caches is None:
                # materialize an empty slot-pool cache from this prototype
                def pool(path, c):
                    d = bdim(path)
                    shape = list(c.shape)
                    shape[d] = self.serve.slots
                    return jnp.zeros(shape, c.dtype)
                self.caches = jax.tree_util.tree_map_with_path(pool, caches)
                # per-token cache bytes for the ledger, measured from the
                # one-lane prototype (length-independent recurrent state is
                # amortized over the max_len the cache was sized for)
                self._kv_token_bytes = sum(
                    int(np.prod(c.shape)) * c.dtype.itemsize
                    for c in jax.tree_util.tree_leaves(caches)
                ) // self.serve.max_len
            # write this lane's cache slice
            def write(path, pool, c):
                return jax.lax.dynamic_update_slice_in_dim(pool, c, i, bdim(path))
            self.caches = jax.tree_util.tree_map_with_path(
                write, self.caches, caches)
            lane.request_id = rid
            lane.pos = len(prompt)
            lane.remaining = max_new - 1
            lane.tokens = [first]
        return prefill_tokens

    def _record_step(self, step_t0: float, prefill_tokens: int,
                     decode_tokens: int, read_tokens: int) -> None:
        """One shared-schema ledger row (obs.ledger.STEP_SCHEMA — identical
        keys to the paged engine; paged-only fields stay at the schema's
        zero defaults: this engine never shares KV, never speculates, and
        has no block pool).  Byte components are real, not parity zeros:
        weights stream once per step, processed tokens write cache state,
        reads cover each participant's visible context."""
        tokens = prefill_tokens + decode_tokens
        row = self.metrics.record(
            tokens=tokens,
            prefill_tokens=prefill_tokens,
            # dense prefill is never padded: real == scheduled
            prefill_real_tokens=prefill_tokens,
            decode_tokens=decode_tokens,
            queue_depth=len(self._queue),
            param_bytes=self._param_bytes,
            kv_write_bytes=tokens * self._kv_token_bytes,
            kv_read_bytes=read_tokens * self._kv_token_bytes,
            step_wall_us=(self.obs.now_us() - step_t0
                          if self.obs.enabled else 0.0),
        )
        if self.obs.enabled:
            self.obs.trace.complete(
                "step", step_t0, row["step_wall_us"], pid=PID_SERVING,
                tid=TID_ENGINE, cat="step",
                args={"step": row["step"], "tokens": tokens,
                      "hbm_bytes": row["hbm_bytes"]})

    def step(self):
        """One batched decode step across all active lanes."""
        obs = self.obs
        step_t0 = obs.now_us() if obs.enabled else 0.0
        prefill_tokens = self._admit()
        active = [l for l in self.lanes if l.request_id is not None]
        if not active:
            if prefill_tokens:
                # every admitted request finished on its prefill-sampled
                # token (max_new=1 / instant eos): still record the burst,
                # or flatness_cov() under-reports exactly the spikes this
                # engine is the baseline for
                self._record_step(step_t0, prefill_tokens, 0,
                                  prefill_tokens)
                return True
            return False
        toks = np.zeros((self.serve.slots, 1), np.int32)
        for i, lane in enumerate(self.lanes):
            if lane.request_id is not None and lane.tokens:
                toks[i, 0] = lane.tokens[-1]
        # single shared pos isn't valid for heterogeneous lanes, so we run
        # one decode call per unique pos group — and merge back ONLY the
        # group's cache rows: decode_step writes KV at `pos` (and advances
        # recurrent state) for EVERY batch row, which would clobber
        # out-of-group lanes' history at that position.  (The paged engine
        # avoids all of this with per-lane position vectors.)
        pos_groups: dict[int, list[int]] = {}
        for i, lane in enumerate(self.lanes):
            if lane.request_id is not None:
                pos_groups.setdefault(lane.pos, []).append(i)
        decode_tokens = 0
        read_tokens = prefill_tokens   # prefill self-attends its context
        for pos, lanes_at in pos_groups.items():
            t0 = obs.now_us() if obs.enabled else 0.0
            logits, new_caches = self._decode(
                self.params, jnp.asarray(toks), self.caches, pos)
            if obs.enabled:
                obs.trace.complete(
                    "decode", t0, obs.now_us() - t0, pid=PID_SERVING,
                    tid=TID_ENGINE, cat="phase",
                    args={"pos": pos, "lanes": len(lanes_at)})
            in_group = np.zeros((self.serve.slots,), bool)
            in_group[lanes_at] = True

            def merge(path, old, new):
                d = 1 if tf.is_stacked_cache_path(path) else 0
                mask = jnp.asarray(in_group).reshape(
                    (1,) * d + (-1,) + (1,) * (old.ndim - d - 1))
                return jnp.where(mask, new, old)

            self.caches = jax.tree_util.tree_map_with_path(
                merge, self.caches, new_caches)
            for i in lanes_at:
                lane = self.lanes[i]
                nxt = sample_token(self.serve, lane.request_id,
                                   len(lane.tokens), logits[i, -1])
                lane.tokens.append(nxt)
                lane.pos += 1
                lane.remaining -= 1
                decode_tokens += 1
                read_tokens += lane.pos
                done = lane.remaining <= 0 or (
                    self.serve.eos_token is not None and nxt == self.serve.eos_token)
                if done:
                    self._finish(lane.request_id, lane.tokens)
                    self.lanes[i] = _Lane()
        self._record_step(step_t0, prefill_tokens, decode_tokens,
                          read_tokens)
        return True

    def run(self, max_steps: int = 10_000):
        steps = 0
        while self.pending and steps < max_steps:
            self.step()
            steps += 1
        return self._results
