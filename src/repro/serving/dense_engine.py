"""Dense-cache serving engine (the pre-paged seed design), kept as

  * the fallback for recurrent architectures (mamba/xlstm blocks carry O(1)
    state, not paged KV — chunked prefill of padded prompts would push pad
    tokens through their state update);
  * the baseline the paged engine's parity tests and BENCH_serving.json
    benchmarks compare against.

Design (seed): `slots` decode lanes over a dense `(slots, max_len)` cache;
finished lanes are refilled from the queue by re-running whole-prompt
prefill (one jit trace PER DISTINCT PROMPT LENGTH) and decode runs one call
per distinct lane position — both the bursty anti-patterns the paged engine
(`serving.engine.ServingEngine`) removes.

Per-step token counts are recorded into `self.metrics` so the serving
benchmark can report this engine's burstiness (tokens/step CoV) next to the
paged engine's flat schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.schedule import tokens_per_step_cov
from repro.models import transformer as tf
from repro.serving.engine import ServeConfig, sample_token

Pytree = Any


@dataclasses.dataclass
class _Lane:
    request_id: int | None = None
    pos: int = 0
    remaining: int = 0
    tokens: list = dataclasses.field(default_factory=list)


class DenseServingEngine:
    def __init__(self, cfg: ModelConfig, params: Pytree, serve: ServeConfig):
        if serve.dense_kernel is not None:
            cfg = cfg.with_(dense_kernel=serve.dense_kernel)
        self.cfg = cfg
        self.params = params
        self.serve = serve
        self.lanes = [_Lane() for _ in range(serve.slots)]
        self._queue: list[tuple[int, np.ndarray, int]] = []
        self._results: dict[int, list[int]] = {}
        self._next_id = 0
        self.metrics: list[dict] = []
        self.trace_counts = {"prefill": 0, "decode": 0}

        def _prefill_one(params, tokens):
            self.trace_counts["prefill"] += 1
            batch = {"tokens": tokens}
            return tf.prefill(params, cfg, batch, max_len=serve.max_len)

        def _decode(params, toks, caches, pos_scalar):
            self.trace_counts["decode"] += 1
            return tf.decode_step(params, cfg, toks, caches, pos_scalar)

        self._prefill = jax.jit(_prefill_one)
        self._decode = jax.jit(_decode)
        self.caches = None

    # ---------------------------------------------------------------- API
    def submit(self, prompt: list[int], max_new_tokens: int = 32) -> int:
        rid = self._next_id
        self._next_id += 1
        self._queue.append((rid, np.asarray(prompt, np.int32), max_new_tokens))
        return rid

    def result(self, rid: int) -> list[int] | None:
        return self._results.get(rid)

    @property
    def pending(self) -> int:
        return len(self._queue) + sum(1 for l in self.lanes if l.request_id is not None)

    def flatness_cov(self) -> float:
        """Coefficient of variation of tokens/step (prefill bursts make the
        dense engine's high; the paged engine's is the flat comparison)."""
        return tokens_per_step_cov([m["tokens"] for m in self.metrics])

    # ------------------------------------------------------------ engine
    def _admit(self) -> int:
        """Fill idle lanes from the queue (continuous batching).  Returns
        prefill tokens processed (whole prompts — the bursty phase)."""
        prefill_tokens = 0
        for i, lane in enumerate(self.lanes):
            if lane.request_id is not None or not self._queue:
                continue
            rid, prompt, max_new = self._queue.pop(0)
            logits, caches = self._prefill(self.params, prompt[None, :])
            prefill_tokens += len(prompt)
            first = sample_token(self.serve, rid, 0, logits[0, -1])
            if max_new <= 1 or (self.serve.eos_token is not None
                                and first == self.serve.eos_token):
                # finished on the prefill-sampled token: never occupies a
                # lane (matches the paged engine's _maybe_finish semantics)
                self._results[rid] = [first]
                continue
            # batch dim is 1 for stacked ("blocks") cache leaves, 0 otherwise
            def bdim(path):
                return 1 if tf.is_stacked_cache_path(path) else 0
            if self.caches is None:
                # materialize an empty slot-pool cache from this prototype
                def pool(path, c):
                    d = bdim(path)
                    shape = list(c.shape)
                    shape[d] = self.serve.slots
                    return jnp.zeros(shape, c.dtype)
                self.caches = jax.tree_util.tree_map_with_path(pool, caches)
            # write this lane's cache slice
            def write(path, pool, c):
                return jax.lax.dynamic_update_slice_in_dim(pool, c, i, bdim(path))
            self.caches = jax.tree_util.tree_map_with_path(
                write, self.caches, caches)
            lane.request_id = rid
            lane.pos = len(prompt)
            lane.remaining = max_new - 1
            lane.tokens = [first]
        return prefill_tokens

    def step(self):
        """One batched decode step across all active lanes."""
        prefill_tokens = self._admit()
        active = [l for l in self.lanes if l.request_id is not None]
        if not active:
            if prefill_tokens:
                # every admitted request finished on its prefill-sampled
                # token (max_new=1 / instant eos): still record the burst,
                # or flatness_cov() under-reports exactly the spikes this
                # engine is the baseline for
                self.metrics.append({
                    "step": len(self.metrics),
                    "tokens": prefill_tokens,
                    "prefill_tokens": prefill_tokens,
                    "decode_tokens": 0,
                    "queue_depth": len(self._queue),
                    # schema parity with the paged engine's prefix-cache and
                    # speculation metrics: the dense engine never shares KV
                    # and never speculates
                    "prefix_hit_tokens": 0,
                    "blocks_shared": 0,
                    "verify_tokens": 0,
                    "drafted_tokens": 0,
                    "accepted_tokens": 0,
                    "acceptance_rate": 0.0,
                })
                return True
            return False
        toks = np.zeros((self.serve.slots, 1), np.int32)
        for i, lane in enumerate(self.lanes):
            if lane.request_id is not None and lane.tokens:
                toks[i, 0] = lane.tokens[-1]
        # single shared pos isn't valid for heterogeneous lanes, so we run
        # one decode call per unique pos group — and merge back ONLY the
        # group's cache rows: decode_step writes KV at `pos` (and advances
        # recurrent state) for EVERY batch row, which would clobber
        # out-of-group lanes' history at that position.  (The paged engine
        # avoids all of this with per-lane position vectors.)
        pos_groups: dict[int, list[int]] = {}
        for i, lane in enumerate(self.lanes):
            if lane.request_id is not None:
                pos_groups.setdefault(lane.pos, []).append(i)
        decode_tokens = 0
        for pos, lanes_at in pos_groups.items():
            logits, new_caches = self._decode(
                self.params, jnp.asarray(toks), self.caches, pos)
            in_group = np.zeros((self.serve.slots,), bool)
            in_group[lanes_at] = True

            def merge(path, old, new):
                d = 1 if tf.is_stacked_cache_path(path) else 0
                mask = jnp.asarray(in_group).reshape(
                    (1,) * d + (-1,) + (1,) * (old.ndim - d - 1))
                return jnp.where(mask, new, old)

            self.caches = jax.tree_util.tree_map_with_path(
                merge, self.caches, new_caches)
            for i in lanes_at:
                lane = self.lanes[i]
                nxt = sample_token(self.serve, lane.request_id,
                                   len(lane.tokens), logits[i, -1])
                lane.tokens.append(nxt)
                lane.pos += 1
                lane.remaining -= 1
                decode_tokens += 1
                done = lane.remaining <= 0 or (
                    self.serve.eos_token is not None and nxt == self.serve.eos_token)
                if done:
                    self._results[lane.request_id] = lane.tokens
                    self.lanes[i] = _Lane()
        self.metrics.append({
            "step": len(self.metrics),
            "tokens": prefill_tokens + decode_tokens,
            "prefill_tokens": prefill_tokens,
            "decode_tokens": decode_tokens,
            "queue_depth": len(self._queue),
            "prefix_hit_tokens": 0,
            "blocks_shared": 0,
            "verify_tokens": 0,
            "drafted_tokens": 0,
            "accepted_tokens": 0,
            "acceptance_rate": 0.0,
        })
        return True

    def run(self, max_steps: int = 10_000):
        steps = 0
        while self.pending and steps < max_steps:
            self.step()
            steps += 1
        return self._results
