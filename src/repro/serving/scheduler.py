"""Token-budget continuous-batching scheduler with GPP-style chunked prefill.

The paper's problem: a bursty off-chip phase (weight rewrite) alternating
with compute starves the bus, so GPP splits the burst into chunks and issues
one chunk per compute slot — traffic goes flat.  Serving has the same
anti-pattern on the *request* axis: whole-prompt prefill is the burst, decode
steps are the compute slots.  This scheduler applies the same move:

  * prefill is split into fixed-size chunks (a multiple of the KV block
    size) and AT MOST ONE chunk runs per engine step, interleaved with the
    batched decode of every decode-phase lane — per-step token count (and
    hence per-step HBM traffic: weights stream once per step, KV writes are
    proportional to tokens) stays flat at ~`chunk + decode_lanes` instead of
    alternating `len(prompt)` spikes with single-token trickles;
  * one chunk size means ONE compiled prefill shape and one decode shape —
    the engine never re-jits per prompt length.

Shared-prefix reuse (serving/prefix.py): when a `PrefixCache` is attached,
admission probes it with the request's context and maps the matched blocks
straight into the lane's table — prefill then STARTS at the matched token
count (`Request.cached_tokens`), skipping those chunks entirely, so the
per-step budget they would have burned goes to decode and other prefills
instead.  A partially-filled matched tail block is mapped copy-on-write
(`fork_block` per layer group; the engine copies the pool rows before any
write).  Preemption resume re-probes: a victim's shares are dropped with its
blocks at preemption and the fresh admission path runs the probe again, so
a stale hit can never outlive the blocks it pointed at.

Policies:
  * FCFS admission: the waiting queue is served strictly in submission
    order; a free lane always takes the queue head.
  * Block pressure: when the shared pool runs dry the prefix index first
    LRU-evicts zero-lane-ref cached prefixes; only when nothing cold is
    left does the YOUNGEST running request get preempted (recompute-style:
    its blocks are freed and it re-queues at the front with its generated
    tokens carried, to be re-prefilled — possibly from cache — on resume).
    Victims are strictly younger than the requester, so the oldest request
    always makes progress — no starvation.

Pure host-side logic (no jax): unit-testable without a model.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from repro.core.schedule import plan_verify_budget, round_up
from repro.obs.trace import NULL_TRACE, PID_SERVING, TID_LANE0
from repro.serving.cache import GroupedPagedCache, PagedKVCache  # noqa: F401


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (plen,) int32
    max_new: int
    # mutable progress state -------------------------------------------------
    produced: list = dataclasses.field(default_factory=list)  # generated ids
    lane: int = -1
    context: "np.ndarray | None" = None  # tokens being (re-)prefilled
    prefill_pos: int = 0                 # next un-prefilled position
    decode_pos: int = -1                 # next KV write position in decode
    preemptions: int = 0
    cached_tokens: int = 0               # context tokens served by the prefix
                                         # cache at the LAST admission (their
                                         # prefill chunks are skipped)

    @property
    def plen(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def remaining(self) -> int:
        return self.max_new - len(self.produced)


@dataclasses.dataclass(frozen=True)
class PrefillWork:
    lane: int
    rid: int
    tokens: np.ndarray   # (chunk,) int32, zero-padded past the context
    start_pos: int
    last_idx: int        # chunk-local index of the context's last real token
    final: bool
    real_tokens: int     # non-pad tokens in this chunk


@dataclasses.dataclass(frozen=True)
class VerifyWork:
    """One batched speculative-verify call: every decode-phase lane rides
    it (draftless lanes with an empty draft — their row degenerates to
    plain decode), so across a mixed workload the engine still traces ONE
    verify shape and ONE decode shape."""
    lanes: "tuple[int, ...]"              # decode-phase lanes, rid order
    drafts: "tuple[tuple[int, ...], ...]"  # per lane, possibly ()

    @property
    def draft_tokens(self) -> int:
        return sum(len(d) for d in self.drafts)


@dataclasses.dataclass(frozen=True)
class StepPlan:
    prefill: Optional[PrefillWork]
    decode_lanes: "tuple[int, ...]"
    preempted: "tuple[int, ...]"      # rids preempted while planning
    prefix_hit_tokens: int = 0        # context tokens served from the prefix
                                      # cache by admissions in this plan
    verify: "VerifyWork | None" = None  # replaces decode_lanes when set

    @property
    def scheduled_tokens(self) -> int:
        """Tokens this step carries (pads included: they occupy the same
        compute/HBM footprint — this is the flatness quantity)."""
        v = (sum(1 + len(d) for d in self.verify.drafts)
             if self.verify else 0)
        return (len(self.prefill.tokens) if self.prefill else 0) \
            + len(self.decode_lanes) + v


class ChunkedPrefillScheduler:
    PREFILL = "prefill"
    DECODE = "decode"

    def __init__(self, cache, *, slots: int, chunk: int, prefix=None,
                 draft_len: int = 0, draft_fn=None, token_budget: int = 0,
                 trace=None):
        bs = cache.cfg.block_size
        if chunk < 1 or chunk % bs:
            raise ValueError(f"chunk {chunk} must be a positive multiple of "
                             f"the block size {bs}")
        if prefix is not None and not isinstance(cache, GroupedPagedCache):
            raise ValueError("prefix caching needs a GroupedPagedCache "
                             "(per-group tables + refcounted shares)")
        if draft_len < 0:
            raise ValueError("draft_len >= 0")
        self.cache = cache
        self.slots = slots
        self.chunk = chunk
        self.prefix = prefix
        # speculative decoding: draft_fn(req, cap) -> up-to-cap int tokens
        # the engine guesses will follow req's stream; verify scores them
        # in one batched call.  token_budget bounds drafts to the step's
        # flatness slack (plan_verify_budget).
        self.draft_len = draft_len
        self.draft_fn = draft_fn
        self.token_budget = token_budget
        # scheduling-decision instants (admit/resume/preempt, with prefix-
        # hit annotations) land on the owning lane's trace track; the
        # default NULL_TRACE makes every emit a no-op
        self.trace = trace if trace is not None else NULL_TRACE
        self.waiting: "deque[Request]" = deque()
        self.running: "dict[int, Request]" = {}     # lane -> Request
        self.phase: "dict[int, str]" = {}           # lane -> PREFILL|DECODE
        self.max_len = cache.cfg.max_len

    # ---------------------------------------------------------------- API
    def submit(self, req: Request) -> None:
        # worst-case resume context is prompt + (max_new - 1) generated
        # tokens, padded up to a chunk multiple — must fit the block table
        if round_up(req.plen + req.max_new, self.chunk) > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {req.plen} + max_new "
                f"{req.max_new} cannot fit the {self.max_len}-token table "
                f"(chunk {self.chunk})")
        if req.max_new < 1:
            raise ValueError("max_new >= 1")
        self.waiting.append(req)

    @property
    def pending(self) -> int:
        return len(self.waiting) + len(self.running)

    @property
    def queue_depth(self) -> int:
        return len(self.waiting)

    def request_at(self, lane: int) -> Request:
        return self.running[lane]

    def to_decode(self, lane: int) -> None:
        """Engine signal: final chunk done, first token sampled."""
        req = self.running[lane]
        self.phase[lane] = self.DECODE
        req.decode_pos = len(req.context)

    def finish(self, lane: int) -> Request:
        req = self.running.pop(lane)
        self.phase.pop(lane)
        self.cache.free_lane(lane)
        req.lane = -1
        return req

    # ---------------------------------------------------------- planning
    def _free_lanes(self) -> "list[int]":
        return [l for l in range(self.slots) if l not in self.running]

    def _probe_prefix(self, req: Request) -> int:
        """Map the longest reusable cached prefix of `req.context` into the
        lane's tables.  Fully-matched blocks are shared read-only; a
        partially-matched tail block is shared then immediately forked
        (copy-on-write) per layer group, since the lane will append into it.
        Returns the cached token count (prefill starts there)."""
        hit = self.prefix.match(req.context, max_len=self.max_len,
                                chunk=self.chunk)
        C = hit.tokens
        if not C:
            return 0
        bs = self.cache.cfg.block_size
        nfull = C // bs
        self.cache.share_blocks(
            req.lane, tuple(list(b) for b in hit.blocks))
        if hit.tail is not None:
            self.cache.share_blocks(
                req.lane, tuple([t] for t in hit.tail))
            if not self.cache.fork_tail(req.lane, nfull):
                # pool too dry to copy the tail block (admission never
                # preempts).  `match` validated window-null feasibility at
                # the ORIGINAL C only, so on a model with windowed groups
                # the block-aligned truncation could pull expired null
                # coverage into the live window — drop the whole share
                # there; global-only models keep the always-feasible floor.
                self.cache.drop_last_shared(req.lane)
                if any(h is not None for h in self.cache.horizons):
                    self.cache.free_lane(req.lane)
                    return 0
                C = nfull * bs
        return C

    def _admit(self) -> int:
        hit_tokens = 0
        for lane in self._free_lanes():
            if not self.waiting:
                break
            req = self.waiting.popleft()
            req.lane = lane
            req.context = np.concatenate(
                [req.prompt, np.asarray(req.produced, np.int32)])
            req.decode_pos = -1
            req.cached_tokens = (self._probe_prefix(req)
                                 if self.prefix is not None else 0)
            req.prefill_pos = req.cached_tokens
            hit_tokens += req.cached_tokens
            self.running[lane] = req
            self.phase[lane] = self.PREFILL
            if self.trace.enabled:
                self.trace.instant(
                    "resume" if req.preemptions else "admit",
                    pid=PID_SERVING, tid=TID_LANE0 + lane, cat="sched",
                    args={"rid": req.rid, "context_tokens": len(req.context),
                          "prefix_hit_tokens": req.cached_tokens,
                          "preemptions": req.preemptions})
        return hit_tokens

    def _preempt_youngest(self, than_rid: int) -> "Request | None":
        """Free the youngest running request strictly younger than
        `than_rid`; re-queue it at the FRONT (it stays ahead of never-
        admitted requests, preserving FCFS).  The victim's prefix-cache
        shares are dropped with its blocks; the fresh admission on resume
        RE-PROBES the index, so no stale hit survives preemption."""
        victims = [r for r in self.running.values() if r.rid > than_rid]
        if not victims:
            return None
        victim = max(victims, key=lambda r: r.rid)
        lane = victim.lane
        self.cache.free_lane(lane)
        self.running.pop(lane)
        self.phase.pop(lane)
        victim.lane = -1
        victim.context = None
        victim.prefill_pos = 0
        victim.decode_pos = -1
        victim.cached_tokens = 0
        victim.preemptions += 1
        self.waiting.appendleft(victim)
        if self.trace.enabled:
            self.trace.instant(
                "preempt", pid=PID_SERVING, tid=TID_LANE0 + lane,
                cat="sched",
                args={"rid": victim.rid, "for_rid": than_rid,
                      "produced": len(victim.produced)})
        return victim

    def _ensure_blocks(self, req: Request, upto_pos: int,
                       preempted: "list[int]") -> bool:
        while not self.cache.ensure(req.lane, upto_pos):
            if self.prefix is not None and self.prefix.evict(
                    self.cache.blocks_needed(req.lane, upto_pos)):
                continue                   # cold cached prefixes go first
            victim = self._preempt_youngest(req.rid)
            if victim is None:
                return False
            preempted.append(victim.rid)
        return True

    def schedule(self) -> "StepPlan | None":
        """Plan one engine step: at most one prefill chunk + every decode
        lane whose next block is (made) available.  Requests are visited
        oldest-first, so preemption victims (always younger) are never
        already in the plan.  Returns None when nothing is runnable."""
        hit_tokens = self._admit()
        if not self.running:
            return None
        preempted: "list[int]" = []
        prefill: "PrefillWork | None" = None
        decode: "list[int]" = []
        for req in sorted(self.running.values(), key=lambda r: r.rid):
            if req.lane not in self.running or self.running[req.lane] is not req:
                continue                       # preempted while planning
            if self.phase[req.lane] == self.DECODE:
                if self._ensure_blocks(req, req.decode_pos, preempted):
                    decode.append(req.lane)
                continue
            if prefill is not None:
                continue                       # one chunk per step (one shape)
            ctx = req.context
            start = req.prefill_pos            # cached_tokens on chunk one —
            #                                    may be ANY token index; the
            #                                    paged write path scatters at
            #                                    token granularity
            if not self._ensure_blocks(req, start + self.chunk - 1, preempted):
                continue
            toks = np.zeros(self.chunk, np.int32)
            real = ctx[start : min(len(ctx), start + self.chunk)]
            toks[: len(real)] = real
            final = start + self.chunk >= len(ctx)
            prefill = PrefillWork(
                lane=req.lane, rid=req.rid, tokens=toks, start_pos=start,
                last_idx=(len(ctx) - 1 - start) if final else 0,
                final=final, real_tokens=len(real))
            req.prefill_pos = start + self.chunk
        if prefill is None and not decode:
            return None
        verify = self._plan_verify(prefill, decode)
        if verify is not None:
            decode = []                    # those lanes ride the verify call
        # no victim re-filter needed: requests are visited oldest-first and
        # victims are strictly younger than the requester, so a lane already
        # planned can never have been preempted while planning
        return StepPlan(prefill=prefill, decode_lanes=tuple(decode),
                        preempted=tuple(preempted),
                        prefix_hit_tokens=hit_tokens, verify=verify)

    def _plan_verify(self, prefill: "PrefillWork | None",
                     decode: "list[int]") -> "VerifyWork | None":
        """Attach speculative drafts to this step's decode lanes, bounded by
        the flatness slack `plan_verify_budget` leaves after the prefill
        chunk and the decode tokens (drafts mostly ride decode-only steps —
        a prefill-carrying step's chunk already fills the budget).  Drafts
        NEVER preempt or evict: a lane's draft shrinks until its blocks fit
        the free pool (speculative tokens are the lowest-priority bytes in
        the system).  Returns None when no lane drafted anything — the step
        then uses the plain decode shape."""
        if self.draft_len < 1 or self.draft_fn is None or not decode:
            return None
        avail = plan_verify_budget(
            token_budget=self.token_budget,
            prefill_tokens=len(prefill.tokens) if prefill else 0,
            decode_lanes=len(decode))
        drafts: "list[tuple[int, ...]]" = []
        for lane in decode:                # rid order: oldest drafts first
            req = self.running[lane]
            # remaining-1: the verify emits >= 1 token, so at most
            # remaining-1 drafts can ever be accepted — also keeps the
            # write span inside the submit()-validated table extent
            cap = min(self.draft_len, req.remaining - 1, avail)
            d = (np.asarray(self.draft_fn(req, cap), np.int32)[:cap]
                 if cap > 0 else np.zeros((0,), np.int32))
            while len(d) and self.cache.blocks_needed(
                    lane, req.decode_pos + len(d)) > self.cache.num_free:
                d = d[:-1]
            if len(d) and not self.cache.ensure(lane,
                                                req.decode_pos + len(d)):
                d = d[:0]                  # unreachable: fit checked above
            avail -= len(d)
            drafts.append(tuple(int(t) for t in d))
        if not any(drafts):
            return None
        return VerifyWork(lanes=tuple(decode), drafts=tuple(drafts))
