"""Serving subsystem: paged KV cache + chunked-prefill continuous batching.

The paper's generalized ping-pong (GPP) takes a bursty off-chip phase — the
PIM weight rewrite — and chunks it so its traffic is spread evenly across
compute steps, keeping off-chip bandwidth demand flat and every macro busy.
This package is that strategy transplanted onto LLM serving, where
whole-prompt prefill is the burst and decode steps are the compute slots:

  paper concept                  serving analogue
  ----------------------------   ------------------------------------------
  PIM macro                      physical KV block in the shared pool
  macro assignment               per-lane block table (cache.PagedKVCache)
  weight rewrite (the burst)     whole-prompt prefill
  rewrite chunk (1/C of a tile)  one fixed-size prefill chunk
  compute slot                   one batched decode step across lanes
  flat off-chip bandwidth        flat tokens/step => flat HBM bytes/step
  G-deep ring never starving     decode lanes never stall behind a prefill
  runtime adaptation (Fig 7)     preemption by block pressure + resume

Modules:
  cache.py      fixed-size-block paged KV cache: allocator, per-lane block
                tables, REFCOUNTED sharing (share_blocks / copy-on-write
                fork_block), defragmentation; GroupedPagedCache stacks one
                cache per layer group (global vs sliding-window reach) so
                windowed layers reclaim expired blocks independently;
                capacity is `num_blocks`, shared, not `slots x max_len`
                reserved per lane
  prefix.py     PrefixCache — radix-tree shared-prefix KV index: admission
                maps previously computed prompt-prefix blocks straight into
                a lane's tables (prefill skips those chunks), LRU eviction
                of zero-lane-ref leaves under block pressure
  scheduler.py  token-budget continuous-batching scheduler: FCFS admission
                (+ prefix-cache probe), prefill split into chunks
                interleaved with decode, preemption-by-block-pressure with
                recompute resume (prefix eviction runs first)
  engine.py     ServingEngine — composes the three; exactly two jitted step
                shapes (chunked-prefill and pure-decode); per-step metrics
  dense_engine.py  the seed dense-cache engine, kept as the recurrent-arch
                fallback and the benchmark/parity baseline

`make_engine` picks the right engine for an architecture; the chunk size
comes from `core.schedule.plan_serve_chunk`, the same flatness math that
sizes the kernels' DMA rings.
"""
from repro.serving.cache import GroupedPagedCache, PagedKVCache
from repro.serving.dense_engine import DenseServingEngine
from repro.serving.engine import ServeConfig, ServingEngine, make_engine
from repro.serving.prefix import PrefixCache, PrefixHit

__all__ = ["DenseServingEngine", "GroupedPagedCache", "PagedKVCache",
           "PrefixCache", "PrefixHit", "ServeConfig", "ServingEngine",
           "make_engine"]
