"""Mixture-of-Experts layer with sort-based token dispatch.

Why sort-based: the GShard one-hot dispatch einsum ((T,E,C) x (T,D)) books
2*T*E*C*D fake FLOPs into the HLO — it would poison the roofline compute
term.  Here dispatch is gather/scatter (bytes, not FLOPs), and expert FFNs
are batched einsums over (E, C, D) — HLO FLOPs == active-expert FLOPs, which
is what 6*N_active*D accounting expects.

Capacity: C = ceil(T * top_k / E * capacity_factor); overflow tokens are
dropped (their combine weight contributes 0) — standard practice.

EP sharding: the (E, C, D) dispatch buffer and (E, D, F) expert weights are
sharded over the `model` axis on E; XLA inserts the token all-to-alls at the
resharding boundaries.  The expert weight stack is also the paper's flagship
streaming workload (weights >> on-chip memory) — see core/streamer.py.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.kernels.ops import dense, dense_grouped
from repro.models.layers import sds


@dataclasses.dataclass(frozen=True)
class MoeConfig:
    d_model: int
    d_ff: int                      # per-expert hidden
    num_experts: int
    experts_per_token: int
    num_shared_experts: int = 0
    shared_d_ff: int | None = None # defaults to d_ff * num_shared
    capacity_factor: float = 1.25
    act: str = "swiglu"
    router_dtype: object = jnp.float32
    dtype: object = jnp.bfloat16
    dispatch_groups: int = 16      # token groups (aligned to the data axis)
    ep_mode: str = "tp"            # tp | dp (see configs/base.py)
    serve_resident: bool = False   # decode: resident E:model x d_ff:data
    dense_kernel: str = "auto"     # kernels.ops.dense/dense_grouped routing
                                   # for router, expert FFNs, shared experts


def moe_specs(c: MoeConfig):
    sp = {
        "router": sds((c.d_model, c.num_experts), c.dtype),
        "w_gate": sds((c.num_experts, c.d_model, c.d_ff), c.dtype),
        "w_up": sds((c.num_experts, c.d_model, c.d_ff), c.dtype),
        "w_down": sds((c.num_experts, c.d_ff, c.d_model), c.dtype),
    }
    if c.num_shared_experts:
        f = c.shared_d_ff or c.d_ff * c.num_shared_experts
        sp["shared"] = {
            "w_gate": sds((c.d_model, f), c.dtype),
            "w_up": sds((c.d_model, f), c.dtype),
            "w_down": sds((f, c.d_model), c.dtype),
        }
    return sp


def capacity(c: MoeConfig, num_tokens: int) -> int:
    cap = math.ceil(num_tokens * c.experts_per_token / c.num_experts
                    * c.capacity_factor)
    return max(8, int(cap))


def _dispatch_groups(c: MoeConfig, T: int) -> int:
    g = c.dispatch_groups
    while g > 1 and T % g:
        g //= 2
    return max(1, g)


def _ambient_constraint(x, spec):
    """with_sharding_constraint against the ambient mesh, if one is set."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty:
            return x
        names = set(mesh.axis_names)
        if not all(a is None or a in names for a in spec):
            return x
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:  # noqa: BLE001 — constraint is an optimization only
        return x


def _dispatch(p, c: MoeConfig, xt: jnp.ndarray, C: int):
    """Route + scatter one token group into its (E, C, D) buffer (LOCAL —
    the group is a data shard).  Returns (buf, combine metadata)."""
    Tg, D = xt.shape
    k, E = c.experts_per_token, c.num_experts

    logits = dense(xt.astype(c.router_dtype),
                   p["router"].astype(c.router_dtype), mode=c.dense_kernel)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                      # (Tg, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    flat_e = top_e.reshape(-1)                                  # (Tg*k,)
    order = jnp.argsort(flat_e)                                 # stable
    sorted_e = flat_e[order]
    grp_start = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    slot = jnp.arange(Tg * k) - grp_start[sorted_e]
    keep = slot < C
    token_idx = order // k

    buf = jnp.zeros((E, C, D), xt.dtype)
    buf = buf.at[sorted_e, jnp.where(keep, slot, 0)].add(
        jnp.where(keep[:, None], xt[token_idx], 0).astype(xt.dtype))
    w = top_p.reshape(-1)[order]
    return buf, (sorted_e, slot, keep, token_idx, w)


def _grouped_ffn(wg, wu, wd, buf: jnp.ndarray, act: str, mode: str) -> jnp.ndarray:
    """(E, C, D) -> (E, C, D) per-expert FFN through `dense_grouped`: the
    expert weight stack streams under the GPP batched-expert schedule on
    TPU; "ref" routing reproduces the plain batched einsums exactly."""
    if act == "swiglu":
        h = (dense_grouped(buf, wg, activation="silu", mode=mode)
             * dense_grouped(buf, wu, mode=mode))
    else:
        h = dense_grouped(buf, wu, activation="gelu", mode=mode)
    return dense_grouped(h, wd, mode=mode)


def _expert_ffn(p, c: MoeConfig, buf: jnp.ndarray) -> jnp.ndarray:
    """(G, E, C, D) -> (G, E, C, D) expert FFN (grouped streaming matmuls).

    tp mode: expert weights are EP-sharded over `model` and FSDP-sharded
    over `data`.  We GATHER the data shards explicitly before the matmuls —
    the paper's write/compute streaming — because letting the partitioner
    handle the sharded contraction dim makes it all-reduce f32 ACTIVATIONS
    over data instead (measured 16x more bytes on kimi-k2: EXPERIMENTS.md
    §Perf).  The weights cost 2 GB/layer (bf16); the activations 30+ GB.

    The token-group axis G folds into the per-expert row dim (E, G*C, D) so
    each expert's weights stream from HBM once for ALL groups — the grouped
    kernel's outer-ring expert axis."""
    P = jax.sharding.PartitionSpec
    wg, wu, wd = p["w_gate"], p["w_up"], p["w_down"]
    if c.ep_mode == "tp":
        wg = _ambient_constraint(wg, P("model", None, None))
        wu = _ambient_constraint(wu, P("model", None, None))
        wd = _ambient_constraint(wd, P("model", None, None))
    # NB: under an ambient SPMD mesh, dense_grouped's "auto" routing falls
    # back to "ref" (ops._ambient_mesh_active) — pallas_call on these
    # EP/FSDP-sharded stacks would force XLA to all-gather them in full.
    G, E, C, D = buf.shape
    rows = buf.swapaxes(0, 1).reshape(E, G * C, D)
    out = _grouped_ffn(wg, wu, wd, rows, c.act, c.dense_kernel)
    return out.reshape(E, G, C, D).swapaxes(0, 1)


def _combine(out_buf, meta, Tg: int, dtype):
    """Gather expert outputs back to token order (LOCAL per group)."""
    sorted_e, slot, keep, token_idx, w = meta
    gathered = out_buf[sorted_e, jnp.where(keep, slot, 0)]      # (Tg*k, D)
    gathered = jnp.where(keep[:, None],
                         gathered * w[:, None].astype(gathered.dtype), 0)
    # combine in the storage dtype: k<=8 contributions, and f32 here would
    # psum a 4x-bigger tensor across ranks
    return jnp.zeros((Tg, gathered.shape[-1]), dtype).at[token_idx].add(
        gathered.astype(dtype))


def _routed_local(p_routed, c: MoeConfig, xt, C: int, n_local: int):
    """Per-(data x model) shard: dispatch local tokens, run THIS model rank's
    expert slice, combine partials.  Caller psums over `model`."""
    wg, wu, wd = p_routed["w_gate"], p_routed["w_up"], p_routed["w_down"]
    buf, meta = _dispatch(p_routed, c, xt, C)          # (E, C, D) local tokens
    # slice this model rank's experts out of the replicated dispatch
    idx = jax.lax.axis_index("model")
    bufe = jax.lax.dynamic_slice_in_dim(buf, idx * n_local, n_local, 0)
    out_e = _grouped_ffn(wg, wu, wd, bufe, c.act, c.dense_kernel)  # (E_local, C, D)
    # place back into the full-E frame so the combine gather stays simple
    out_buf = jnp.zeros((c.num_experts, C, out_e.shape[-1]), out_e.dtype)
    out_buf = jax.lax.dynamic_update_slice_in_dim(out_buf, out_e, idx * n_local, 0)
    partial = _combine(out_buf, meta, xt.shape[0], xt.dtype)
    return jax.lax.psum(partial, "model")              # (Tg, D)


def _moe_shard_map(p, c: MoeConfig, x: jnp.ndarray, mesh) -> jnp.ndarray:
    """Explicit-schedule routed experts (shard_map over data x model).

    The paper's write/compute structure made literal: the per-layer
    `all_gather` of the data-sharded expert weights is the "rewrite", the
    expert einsums the "compute"; bwd transposes to reduce-scatter.  We use
    shard_map because the SPMD partitioner's implicit choices for this block
    (activation psums fwd, replicate-then-slice bwd) cost 10-60x more bytes
    — measured in EXPERIMENTS.md §Perf."""
    from jax.sharding import PartitionSpec as P
    B, S, D = x.shape
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    tp = mesh.shape.get("model", 1)
    T_local = B * S // dp_size
    C = capacity(c, T_local)
    n_local = c.num_experts // tp

    def local(xb, router, wg, wu, wd):
        xt = xb.reshape(-1, D)
        # the "rewrite": gather this rank's expert slice over the fsdp axis
        wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
        wu = jax.lax.all_gather(wu, "data", axis=1, tiled=True)
        wd = jax.lax.all_gather(wd, "data", axis=1, tiled=True)
        pr = {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}
        out = _routed_local(pr, c, xt, C, n_local)
        return out.reshape(xb.shape)

    return jax.shard_map(
        local,
        mesh=mesh,
        in_specs=(P(dp, None, None), P(None, None),
                  P("model", "data", None), P("model", "data", None),
                  P("model", "data", None)),
        out_specs=P(dp, None, None),
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def _moe_shard_map_serve(p, c: MoeConfig, x: jnp.ndarray, mesh) -> jnp.ndarray:
    """Decode-time routed experts with RESIDENT weights: E sharded over
    `model`, d_ff sharded over `data` — no weight movement at all.  Tokens
    (tiny at decode: B x 1) are replicated inside the block; each shard
    computes its (E_local x F_slice) partial and one small psum over
    (data, model) combines.  kimi-k2: ~44 MB psum/layer vs 2.1 GB of weight
    gathers per token (EXPERIMENTS.md §Perf cell D)."""
    from jax.sharding import PartitionSpec as P
    B, S, D = x.shape
    T = B * S
    C = capacity(c, T)
    tp = mesh.shape.get("model", 1)
    n_local = c.num_experts // tp

    def local(xb, router, wg, wu, wd):
        xt = xb.reshape(T, D)
        buf, meta = _dispatch({"router": router}, c, xt, C)
        idx = jax.lax.axis_index("model")
        bufe = jax.lax.dynamic_slice_in_dim(buf, idx * n_local, n_local, 0)
        out_e = _grouped_ffn(wg, wu, wd, bufe, c.act, c.dense_kernel)  # F-slice partial
        out_buf = jnp.zeros((c.num_experts, C, D), out_e.dtype)
        out_buf = jax.lax.dynamic_update_slice_in_dim(
            out_buf, out_e, idx * n_local, 0)
        partial = _combine(out_buf, meta, T, xt.dtype)
        # experts over model + d_ff slices over data; NOT pod (weights are
        # replicated across pods — summing there would double-count)
        partial = jax.lax.psum(partial, ("model", "data"))
        return partial.reshape(B, S, D)

    return jax.shard_map(
        local,
        mesh=mesh,
        in_specs=(P(None, None, None), P(None, None),
                  P("model", None, "data"), P("model", None, "data"),
                  P("model", "data", None)),
        out_specs=P(None, None, None),
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def _mesh_dp_size(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if mesh is not None and not mesh.empty and a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def _mesh_has(mesh, *axes) -> bool:
    return mesh is not None and not mesh.empty and all(
        a in mesh.axis_names for a in axes)


def moe_apply(p, c: MoeConfig, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, D) -> (B, S, D).

    With a (data, model) mesh ambient, the routed experts run under an
    explicit shard_map schedule (`_moe_shard_map`).  Without one (CPU smoke
    tests), dispatch is grouped and everything stays local."""
    B, S, D = x.shape
    T = B * S
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:  # noqa: BLE001
        mesh = None
    use_sm = (_mesh_has(mesh, "data", "model")
              and c.num_experts % mesh.shape.get("model", 1) == 0
              and T % max(1, _mesh_dp_size(mesh)) == 0)
    use_serve = (c.serve_resident and _mesh_has(mesh, "data", "model")
                 and c.num_experts % mesh.shape.get("model", 1) == 0
                 and T <= 4096)  # tokens replicated inside: decode-sized only
    if use_serve:
        out = _moe_shard_map_serve(p, c, x, mesh)
    elif use_sm:
        out = _moe_shard_map(p, c, x, mesh)
    else:
        G = _dispatch_groups(c, T)
        Tg = T // G
        C = capacity(c, Tg)
        xg = x.reshape(G, Tg, D)
        buf, meta = jax.vmap(lambda xt: _dispatch(p, c, xt, C))(xg)
        out_buf = _expert_ffn(p, c, buf)
        out = jax.vmap(lambda ob, m: _combine(ob, m, Tg, x.dtype))(out_buf, meta)
        out = out.reshape(B, S, D)

    if c.num_shared_experts:
        xt = x.reshape(T, D)
        sh = p["shared"]
        if c.act == "swiglu":
            hs = (dense(xt, sh["w_gate"], activation="silu", mode=c.dense_kernel)
                  * dense(xt, sh["w_up"], mode=c.dense_kernel))
        else:
            hs = dense(xt, sh["w_up"], activation="gelu", mode=c.dense_kernel)
        out = out + dense(hs, sh["w_down"], mode=c.dense_kernel).reshape(B, S, D)

    return out


def aux_load_balance_loss(p, c: MoeConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Switch-style load-balance auxiliary loss (fraction * probability)."""
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    logits = dense(xt.astype(c.router_dtype),
                   p["router"].astype(c.router_dtype), mode=c.dense_kernel)
    probs = jax.nn.softmax(logits, axis=-1)
    top_e = jnp.argmax(probs, axis=-1)
    frac = jnp.bincount(top_e, length=c.num_experts).astype(jnp.float32) / xt.shape[0]
    mean_p = jnp.mean(probs, axis=0)
    return c.num_experts * jnp.sum(frac * mean_p)
