"""Backbone assembler: composes attention/MoE/SSM/xLSTM blocks into the ten
assigned architectures, with scan-over-superblocks and optional GPP weight
streaming (the paper's technique) on the stacked block weights.

Layer layout: `cfg.prefix_pattern` names unstacked leading layers (e.g. the
dense first layer of DeepSeek/Kimi MoEs); `cfg.pattern` is the repeating
superblock (e.g. gemma3's 5 local + 1 global) stacked `num_superblocks`
times and scanned.  "shared_attn" (Zamba2) uses one unstacked param set
reused by every superblock — the paper's weight-reuse limit case.

Entry points:
  param_specs / init_params
  loss_fn(params, cfg, batch)                      training forward + CE
  prefill(params, cfg, batch, max_len)             -> (logits, caches)
  decode_step(params, cfg, tokens, caches, pos)    -> (logits, caches)
  cache_specs(cfg, batch, max_len)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.streamer import StreamSettings, stream_layers
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import (
    cross_entropy, cross_entropy_chunked, embed, embed_specs, init_from_specs, lm_head, lm_head_specs,
    mlp, mlp_specs, rmsnorm, rmsnorm_specs, sds, stack_specs, unembed,
)

Pytree = Any


# ---------------------------------------------------------------------------
# per-kind specs
# ---------------------------------------------------------------------------

def _attn_cfg(cfg: ModelConfig, kind: str) -> attn.AttnConfig:
    window = cfg.window_size if kind.endswith(":window") else None
    return attn.AttnConfig(
        d_model=cfg.d_model,
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim,
        qkv_bias=cfg.qkv_bias,
        rope_theta=cfg.rope_theta,
        window=window,
        kv_lora_rank=cfg.kv_lora_rank,
        q_lora_rank=cfg.q_lora_rank,
        rope_head_dim=cfg.rope_head_dim,
        dtype=cfg.jdtype,
        dense_mode=cfg.dense_kernel,
        paged_mode=cfg.paged_attn_kernel,
    )


def _ssm_cfg(cfg: ModelConfig) -> ssm_mod.SsmConfig:
    return ssm_mod.SsmConfig(
        d_model=cfg.d_model,
        d_inner=cfg.ssm_expansion * cfg.d_model,
        d_state=cfg.ssm_state_dim,
        n_heads=cfg.num_heads,
        dtype=cfg.jdtype,
        dense_mode=cfg.dense_kernel,
    )


def _xlstm_cfg(cfg: ModelConfig) -> xlstm_mod.XlstmConfig:
    return xlstm_mod.XlstmConfig(
        d_model=cfg.d_model, n_heads=cfg.num_heads, dtype=cfg.jdtype,
        dense_mode=cfg.dense_kernel,
    )


def _moe_cfg(cfg: ModelConfig) -> moe_mod.MoeConfig:
    return moe_mod.MoeConfig(
        d_model=cfg.d_model,
        d_ff=cfg.moe_d_ff or cfg.d_ff,
        num_experts=cfg.num_experts,
        experts_per_token=cfg.experts_per_token,
        num_shared_experts=cfg.num_shared_experts,
        capacity_factor=cfg.moe_capacity_factor,
        act=cfg.act,
        dtype=cfg.jdtype,
        ep_mode=cfg.moe_ep_mode,
        serve_resident=cfg.moe_serve_resident,
        dense_kernel=cfg.dense_kernel,
    )


def block_specs(cfg: ModelConfig, kind: str) -> Pytree:
    d, dt = cfg.d_model, cfg.jdtype
    base = kind.split(":")[0]
    if base in ("dense", "shared_attn"):
        return {
            "ln1": rmsnorm_specs(d, dt),
            "attn": attn.attn_specs(_attn_cfg(cfg, kind)),
            "ln2": rmsnorm_specs(d, dt),
            "mlp": mlp_specs(d, cfg.d_ff, dt, cfg.act),
        }
    if base == "moe":
        return {
            "ln1": rmsnorm_specs(d, dt),
            "attn": attn.attn_specs(_attn_cfg(cfg, kind)),
            "ln2": rmsnorm_specs(d, dt),
            "moe": moe_mod.moe_specs(_moe_cfg(cfg)),
        }
    if base == "mamba":
        return {"ln": rmsnorm_specs(d, dt), "ssm": ssm_mod.ssm_specs(_ssm_cfg(cfg))}
    if base in ("mlstm", "slstm"):
        mix = (xlstm_mod.mlstm_specs if base == "mlstm"
               else xlstm_mod.slstm_specs)(_xlstm_cfg(cfg))
        sp = {"ln1": rmsnorm_specs(d, dt), "mix": mix}
        if cfg.d_ff:  # xlstm-1.3b has d_ff == 0: mixer-only blocks
            sp["ln2"] = rmsnorm_specs(d, dt)
            sp["mlp"] = mlp_specs(d, cfg.d_ff, dt, cfg.act)
        return sp
    if base == "cross":
        return {
            "ln1": rmsnorm_specs(d, dt),
            "attn": attn.cross_attn_specs(_attn_cfg(cfg, kind)),
            "ln2": rmsnorm_specs(d, dt),
            "mlp": mlp_specs(d, cfg.d_ff, dt, cfg.act),
        }
    raise ValueError(f"unknown block kind {kind!r}")


def param_specs(cfg: ModelConfig) -> Pytree:
    sp: dict = {}
    if cfg.input_mode == "tokens":
        sp["embed"] = embed_specs(cfg.vocab_size, cfg.d_model, cfg.jdtype)
    sp["prefix"] = [block_specs(cfg, k) for k in cfg.prefix_pattern]
    S = cfg.num_superblocks
    sp["blocks"] = {
        f"b{i}": stack_specs(block_specs(cfg, k), S)
        for i, k in enumerate(cfg.pattern)
        if not k.startswith("shared_attn")
    }
    if any(k.startswith("shared_attn") for k in cfg.pattern):
        sp["shared"] = block_specs(cfg, "shared_attn")
    sp["final_norm"] = rmsnorm_specs(cfg.d_model, cfg.jdtype)
    if not cfg.tie_embeddings:
        sp["lm_head"] = lm_head_specs(cfg.vocab_size, cfg.d_model, cfg.jdtype)
    return sp


def init_params(cfg: ModelConfig, key: jax.Array) -> Pytree:
    return init_from_specs(param_specs(cfg), key)


# ---------------------------------------------------------------------------
# block application (training / full-sequence mode)
# ---------------------------------------------------------------------------

def apply_block(cfg: ModelConfig, kind: str, p: Pytree, x: jnp.ndarray,
                positions: jnp.ndarray, enc: jnp.ndarray | None) -> jnp.ndarray:
    base = kind.split(":")[0]
    ac = _attn_cfg(cfg, kind)
    if base in ("dense", "shared_attn", "moe"):
        h = rmsnorm(p["ln1"], x)
        if ac.is_mla:
            h = attn.mla_forward(p["attn"], ac, h, positions)
        else:
            h = attn.gqa_forward(p["attn"], ac, h, positions)
        x = x + h
        h = rmsnorm(p["ln2"], x)
        if base == "moe":
            h = moe_mod.moe_apply(p["moe"], _moe_cfg(cfg), h)
        else:
            h = mlp(p["mlp"], h, cfg.act, dense_mode=cfg.dense_kernel)
        return x + h
    if base == "mamba":
        return x + ssm_mod.ssm_forward(p["ssm"], _ssm_cfg(cfg), rmsnorm(p["ln"], x))
    if base in ("mlstm", "slstm"):
        fwd = xlstm_mod.mlstm_forward if base == "mlstm" else xlstm_mod.slstm_forward
        x = x + fwd(p["mix"], _xlstm_cfg(cfg), rmsnorm(p["ln1"], x))
        if cfg.d_ff:
            x = x + mlp(p["mlp"], rmsnorm(p["ln2"], x), cfg.act, dense_mode=cfg.dense_kernel)
        return x
    if base == "cross":
        h = attn.cross_attn_forward(p["attn"], ac, rmsnorm(p["ln1"], x), enc)
        x = x + h
        return x + mlp(p["mlp"], rmsnorm(p["ln2"], x), cfg.act, dense_mode=cfg.dense_kernel)
    raise ValueError(kind)


def _superblock_apply(cfg: ModelConfig, shared: Pytree | None, enc):
    """Returns apply_fn(carry, stacked_ws_for_one_superblock) for scan/stream."""

    def apply_fn(carry, ws):
        x, positions = carry
        for i, kind in enumerate(cfg.pattern):
            if kind.startswith("shared_attn"):
                x = apply_block(cfg, kind, shared, x, positions, enc)
            else:
                x = apply_block(cfg, kind, ws[f"b{i}"], x, positions, enc)
        return (x, positions), None

    return apply_fn


def _wsc(x, pspec, mesh):
    """with_sharding_constraint that tolerates mesh-less runs."""
    if mesh is None or pspec is None or getattr(mesh, "empty", False):
        return x
    from jax.sharding import NamedSharding
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, pspec))


def forward(params: Pytree, cfg: ModelConfig, batch: dict,
            mesh=None, shard_specs=None, full_specs=None,
            return_hidden: bool = False, act_pspec=None) -> jnp.ndarray:
    """Full-sequence forward to logits.  batch keys: tokens|embeds, [enc]."""
    if cfg.input_mode == "tokens":
        x = _embed_tokens(params, cfg, batch["tokens"])
    else:
        x = batch["embeds"].astype(cfg.jdtype)
    # pin activation layout (batch over dp axes) — XLA otherwise may unshard
    # the batch and blow up attention temp memory
    x = _wsc(x, act_pspec, mesh)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    enc = batch.get("enc")
    if enc is not None:
        enc = enc.astype(cfg.jdtype)

    for kind, p in zip(cfg.prefix_pattern, params["prefix"]):
        x = apply_block(cfg, kind, p, x, positions, enc)

    shared = params.get("shared")
    apply_fn = _superblock_apply(cfg, shared, enc)

    if cfg.stream.mode == "resident" or shard_specs is None:
        def body(carry, ws):
            return apply_fn(carry, ws)
        if cfg.remat == "block":
            body = jax.checkpoint(body)
        (x, _), _ = jax.lax.scan(body, (x, positions), params["blocks"])
    else:
        def stream_apply(carry, ws):
            new_carry, _ = apply_fn(carry, ws)
            return new_carry
        if cfg.remat == "block":
            stream_apply = jax.checkpoint(stream_apply)
        x, _ = stream_layers(
            stream_apply, (x, positions), params["blocks"], cfg.num_superblocks,
            settings=cfg.stream, mesh=mesh,
            shard_specs=shard_specs, full_specs=full_specs,
        )

    if return_hidden:
        return rmsnorm(params["final_norm"], x)
    return _logits_head(params, cfg, x)


def hidden_states(params: Pytree, cfg: ModelConfig, batch: dict,
                  mesh=None, shard_specs=None, full_specs=None,
                  act_pspec=None) -> jnp.ndarray:
    """Forward up to (and including) the final norm — no LM head."""
    return forward(params, cfg, batch, mesh, shard_specs, full_specs,
                   return_hidden=True, act_pspec=act_pspec)


def loss_fn(params: Pytree, cfg: ModelConfig, batch: dict,
            mesh=None, shard_specs=None, full_specs=None,
            act_pspec=None) -> jnp.ndarray:
    x = hidden_states(params, cfg, batch, mesh, shard_specs, full_specs,
                      act_pspec=act_pspec)

    if cfg.tie_embeddings:
        head = lambda xc: unembed(params["embed"], xc)
    else:
        head = lambda xc: lm_head(params["lm_head"], xc)
    return cross_entropy_chunked(head, x, batch["labels"], chunk=512)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _block_cache_specs(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    base = kind.split(":")[0]
    if base in ("dense", "shared_attn", "moe"):
        return attn.cache_specs(_attn_cfg(cfg, kind), batch, max_len)
    if base == "mamba":
        return ssm_mod.ssm_state_specs(_ssm_cfg(cfg), batch)
    if base == "mlstm":
        return xlstm_mod.mlstm_state_specs(_xlstm_cfg(cfg), batch)
    if base == "slstm":
        return xlstm_mod.slstm_state_specs(_xlstm_cfg(cfg), batch)
    if base == "cross":
        return None  # K/V come from the static encoder embeddings
    raise ValueError(kind)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> Pytree:
    S = cfg.num_superblocks
    caches = {
        "prefix": [
            _block_cache_specs(cfg, k, batch, max_len) for k in cfg.prefix_pattern
        ],
        "blocks": {},
    }
    for i, k in enumerate(cfg.pattern):
        cs = _block_cache_specs(cfg, k, batch, max_len)
        if cs is not None:
            caches["blocks"][f"b{i}"] = jax.tree.map(
                lambda s: sds((S, *s.shape), s.dtype), cs
            )
    return caches


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------

def _block_prefill(cfg, kind, p, x, positions, enc, max_len):
    base = kind.split(":")[0]
    ac = _attn_cfg(cfg, kind)
    if base in ("dense", "shared_attn", "moe"):
        h = rmsnorm(p["ln1"], x)
        if ac.is_mla:
            h, cache = attn.mla_prefill(p["attn"], ac, h, positions, max_len)
        else:
            h, cache = attn.gqa_prefill(p["attn"], ac, h, positions, max_len)
        x = x + h
        h = rmsnorm(p["ln2"], x)
        if base == "moe":
            h = moe_mod.moe_apply(p["moe"], _moe_cfg(cfg), h)
        else:
            h = mlp(p["mlp"], h, cfg.act, dense_mode=cfg.dense_kernel)
        return x + h, cache
    if base == "mamba":
        y, st = ssm_mod.ssm_prefill(p["ssm"], _ssm_cfg(cfg), rmsnorm(p["ln"], x))
        return x + y, st
    if base in ("mlstm", "slstm"):
        fn = xlstm_mod.mlstm_prefill if base == "mlstm" else xlstm_mod.slstm_prefill
        y, st = fn(p["mix"], _xlstm_cfg(cfg), rmsnorm(p["ln1"], x))
        x = x + y
        if cfg.d_ff:
            x = x + mlp(p["mlp"], rmsnorm(p["ln2"], x), cfg.act, dense_mode=cfg.dense_kernel)
        return x, st
    if base == "cross":
        return apply_block(cfg, kind, p, x, positions, enc), None
    raise ValueError(kind)


def _block_decode(cfg, kind, p, x, cache, pos, enc):
    base = kind.split(":")[0]
    ac = _attn_cfg(cfg, kind)
    if base in ("dense", "shared_attn", "moe"):
        h = rmsnorm(p["ln1"], x)
        if ac.is_mla:
            h, cache = attn.mla_decode(p["attn"], ac, h, cache, pos)
        else:
            h, cache = attn.gqa_decode(p["attn"], ac, h, cache, pos)
        x = x + h
        h = rmsnorm(p["ln2"], x)
        if base == "moe":
            h = moe_mod.moe_apply(p["moe"], _moe_cfg(cfg), h)
        else:
            h = mlp(p["mlp"], h, cfg.act, dense_mode=cfg.dense_kernel)
        return x + h, cache
    if base == "mamba":
        y, st = ssm_mod.ssm_decode(p["ssm"], _ssm_cfg(cfg), rmsnorm(p["ln"], x), cache)
        return x + y, st
    if base in ("mlstm", "slstm"):
        fn = xlstm_mod.mlstm_decode if base == "mlstm" else xlstm_mod.slstm_decode
        y, st = fn(p["mix"], _xlstm_cfg(cfg), rmsnorm(p["ln1"], x), cache)
        x = x + y
        if cfg.d_ff:
            x = x + mlp(p["mlp"], rmsnorm(p["ln2"], x), cfg.act, dense_mode=cfg.dense_kernel)
        return x, st
    if base == "cross":
        positions = None
        h = attn.cross_attn_forward(p["attn"], ac, rmsnorm(p["ln1"], x), enc)
        x = x + h
        return x + mlp(p["mlp"], rmsnorm(p["ln2"], x), cfg.act, dense_mode=cfg.dense_kernel), None
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# paged prefill / decode (serving subsystem)
# ---------------------------------------------------------------------------
#
# The paged path serves attention-cache architectures (dense / shared_attn /
# moe blocks, incl. window + MLA variants).  Recurrent blocks (mamba /
# mlstm / slstm) carry O(1) state rather than per-token KV, and chunked
# prefill of a *padded* prompt would push pad tokens through their state
# update — they stay on the dense-cache engine (ROADMAP open item: masked
# state updates would lift this).

PAGED_BLOCK_KINDS = ("dense", "shared_attn", "moe")


def is_stacked_cache_path(path) -> bool:
    """True for cache-pytree leaves under the stacked "blocks" group, whose
    leading dim is the superblock stack (so the lane/pool dim sits at axis 1,
    not 0).  Single source of truth for every consumer that needs the
    batch/pool axis of a cache leaf — the layout is defined by `cache_specs`
    / `paged_cache_specs` in this module."""
    return any(getattr(k, "key", None) == "blocks" for k in path)


def supports_paged(cfg: ModelConfig) -> bool:
    kinds = tuple(cfg.prefix_pattern) + tuple(cfg.pattern)
    return (cfg.input_mode == "tokens"
            and all(k.split(":")[0] in PAGED_BLOCK_KINDS for k in kinds))


def layer_reach(cfg: ModelConfig, kind: str) -> str:
    """Attention reach class of a block kind: "window" for sliding-window
    layers (bounded lookback), "global" otherwise — the bucketing key for
    per-layer-group block tables."""
    return "window" if (kind.endswith(":window") and cfg.window_size) \
        else "global"


def layer_group_keys(cfg: ModelConfig) -> "tuple[str, ...]":
    """Distinct attention-reach classes across every layer, in first-
    appearance order.  Each class gets its OWN paged block table + pool id
    space (`serving.cache.GroupedPagedCache`), so sliding-window layers
    reclaim expired blocks even when global layers in the same model pin
    full history — gemma3's 5-local:1-global stack plateaus on the local
    group while only the global group grows."""
    kinds = tuple(cfg.prefix_pattern) + tuple(cfg.pattern)
    keys: "list[str]" = []
    for k in kinds:
        r = layer_reach(cfg, k)
        if r not in keys:
            keys.append(r)
    return tuple(keys) or ("global",)


def layer_group_index(cfg: ModelConfig, kind: str) -> int:
    return layer_group_keys(cfg).index(layer_reach(cfg, kind))


def group_horizons(cfg: ModelConfig) -> "tuple[int | None, ...]":
    """Per layer group: the oldest position its layers can still attend to,
    relative to the current query (None = unbounded).  A group's blocks
    wholly behind its horizon are reclaimable — per group, unlike
    `window_horizon`, which is the whole-model (shared-table) condition."""
    return tuple(cfg.window_size if k == "window" else None
                 for k in layer_group_keys(cfg))


def cache_path_group(cfg: ModelConfig, path) -> int:
    """Layer-group index of a paged-cache pytree leaf, from its tree path
    (the layout defined by `paged_cache_specs`): "prefix"/i leaves follow
    prefix_pattern[i], "blocks"/"b{i}" leaves follow pattern[i].  Engine-
    side pool permutes (defragment) and COW block copies use this to apply
    each group's remap to exactly its own layers' pools."""
    for i, k in enumerate(path):
        key = getattr(k, "key", None)
        if key == "prefix":
            return layer_group_index(cfg, cfg.prefix_pattern[path[i + 1].idx])
        if key == "blocks":
            return layer_group_index(cfg, cfg.pattern[int(path[i + 1].key[1:])])
    raise ValueError(f"not a paged-cache leaf path: {path}")


def _group_table(cfg: ModelConfig, kind: str, tables):
    """Resolve a layer's block table from per-group tables (tuple/list, one
    per `layer_group_keys` entry) or a single shared table (back-compat:
    models whose layers all share one reach)."""
    if isinstance(tables, (tuple, list)):
        return tables[layer_group_index(cfg, kind)]
    return tables


def window_horizon(cfg: ModelConfig) -> "int | None":
    """Oldest position any layer can still attend to, relative to the
    current query position — the block-reclamation horizon.

    Finite only when EVERY layer is sliding-window: block tables are shared
    across layers, so a physical block is reclaimable only once every
    layer's mask has moved past it.  One full-attention (or MLA) layer pins
    the whole history -> None (no reclamation), which is why gemma3's global
    layers keep their full-length KV while an all-local stack plateaus.
    """
    kinds = tuple(cfg.prefix_pattern) + tuple(cfg.pattern)
    if not kinds:
        return None
    for k in kinds:
        if not k.endswith(":window"):
            return None
    return cfg.window_size


def paged_cache_specs(cfg: ModelConfig, num_blocks: int, block_size: int) -> Pytree:
    """Pool ShapeDtypeStructs mirroring `cache_specs`' tree structure, with
    the per-lane (batch, max_len) dims replaced by shared
    (num_blocks, block_size) pools.  Stacked superblock leaves keep their
    leading S dim; physical block ids index the second axis there."""
    if not supports_paged(cfg):
        bad = [k for k in tuple(cfg.prefix_pattern) + tuple(cfg.pattern)
               if k.split(":")[0] not in PAGED_BLOCK_KINDS]
        raise ValueError(
            f"{cfg.name}: paged KV serves attention-cache blocks only; "
            f"unsupported kinds {bad} (use the dense-cache engine)")
    S = cfg.num_superblocks
    caches = {
        "prefix": [
            attn.paged_cache_specs(_attn_cfg(cfg, k), num_blocks, block_size)
            for k in cfg.prefix_pattern
        ],
        "blocks": {},
    }
    for i, k in enumerate(cfg.pattern):
        cs = attn.paged_cache_specs(_attn_cfg(cfg, k), num_blocks, block_size)
        caches["blocks"][f"b{i}"] = jax.tree.map(
            lambda s: sds((S, *s.shape), s.dtype), cs)
    return caches


def _block_prefill_paged(cfg, kind, p, x, cache, table_row, start_pos):
    ac = _attn_cfg(cfg, kind)
    base = kind.split(":")[0]
    row = _group_table(cfg, kind, table_row)
    h = rmsnorm(p["ln1"], x)
    if ac.is_mla:
        h, cache = attn.mla_prefill_paged(p["attn"], ac, h, cache, row,
                                          start_pos)
    else:
        h, cache = attn.gqa_prefill_paged(p["attn"], ac, h, cache, row,
                                          start_pos)
    x = x + h
    h = rmsnorm(p["ln2"], x)
    if base == "moe":
        h = moe_mod.moe_apply(p["moe"], _moe_cfg(cfg), h)
    else:
        h = mlp(p["mlp"], h, cfg.act, dense_mode=cfg.dense_kernel)
    return x + h, cache


def _block_decode_paged(cfg, kind, p, x, cache, tables, positions, active):
    ac = _attn_cfg(cfg, kind)
    base = kind.split(":")[0]
    tb = _group_table(cfg, kind, tables)
    h = rmsnorm(p["ln1"], x)
    if ac.is_mla:
        h, cache = attn.mla_decode_paged(p["attn"], ac, h, cache, tb,
                                         positions, active)
    else:
        h, cache = attn.gqa_decode_paged(p["attn"], ac, h, cache, tb,
                                         positions, active)
    x = x + h
    h = rmsnorm(p["ln2"], x)
    if base == "moe":
        h = moe_mod.moe_apply(p["moe"], _moe_cfg(cfg), h)
    else:
        h = mlp(p["mlp"], h, cfg.act, dense_mode=cfg.dense_kernel)
    return x + h, cache


def _block_verify_paged(cfg, kind, p, x, cache, tables, positions, active,
                        nvalid):
    ac = _attn_cfg(cfg, kind)
    base = kind.split(":")[0]
    tb = _group_table(cfg, kind, tables)
    h = rmsnorm(p["ln1"], x)
    if ac.is_mla:
        h, cache = attn.mla_verify_paged(p["attn"], ac, h, cache, tb,
                                         positions, active, nvalid)
    else:
        h, cache = attn.gqa_verify_paged(p["attn"], ac, h, cache, tb,
                                         positions, active, nvalid)
    x = x + h
    h = rmsnorm(p["ln2"], x)
    if base == "moe":
        h = moe_mod.moe_apply(p["moe"], _moe_cfg(cfg), h)
    else:
        h = mlp(p["mlp"], h, cfg.act, dense_mode=cfg.dense_kernel)
    return x + h, cache


def _embed_tokens(params, cfg: ModelConfig, tokens):
    x = embed(params["embed"], tokens)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def _logits_head(params, cfg: ModelConfig, x):
    x = rmsnorm(params["final_norm"], x)
    return (unembed(params["embed"], x) if cfg.tie_embeddings
            else lm_head(params["lm_head"], x))


def prefill_chunk(params: Pytree, cfg: ModelConfig, tokens, caches,
                  table_row, start_pos, last_idx):
    """Process one block-aligned prompt chunk for a single lane.

    tokens: (1, chunk) — the chunk's token ids (pads beyond the real prompt
      are harmless: their pool slots are overwritten by decode writes at the
      same absolute positions, and the causal mask hides them until then).
    table_row: the lane's block table(s) — a (1, max_blocks) array, or a
      tuple of one such array per layer group (`layer_group_keys`) when
      window and global layers keep separate tables.
    start_pos: traced scalar — absolute position of tokens[0].  ANY token
      index: with a prefix-cache hit the first chunk starts at the matched
      token count, mid-block when a shared tail block was forked; the paged
      KV write scatters per token and the read masks are position-exact, so
      no alignment is required.
    last_idx: traced scalar — chunk-local index whose logits the engine
      samples from (the prompt's true last token on the final chunk; ignored
      on earlier chunks).

    Returns (logits (1, vocab), caches).  The chunk size is the ONLY shape
    this function is compiled for — the generalized-ping-pong move applied
    to prefill: a bursty whole-prompt rewrite becomes fixed-size chunks
    interleaved with decode steps, so per-step token count (and HBM traffic)
    stays flat and jit shapes are bounded.
    """
    x = _embed_tokens(params, cfg, tokens)
    new_prefix = []
    for kind, p, c in zip(cfg.prefix_pattern, params["prefix"], caches["prefix"]):
        x, c = _block_prefill_paged(cfg, kind, p, x, c, table_row, start_pos)
        new_prefix.append(c)

    shared = params.get("shared")

    def body(carry, xs):
        x = carry
        ws, cache = xs
        new_caches = {}
        for i, kind in enumerate(cfg.pattern):
            p = shared if kind.startswith("shared_attn") else ws[f"b{i}"]
            x, c_out = _block_prefill_paged(cfg, kind, p, x, cache[f"b{i}"],
                                            table_row, start_pos)
            new_caches[f"b{i}"] = c_out
        return x, new_caches

    x, blk_caches = jax.lax.scan(body, x, (params["blocks"], caches["blocks"]))
    x_last = jax.lax.dynamic_slice_in_dim(x, last_idx, 1, axis=1)
    logits = _logits_head(params, cfg, x_last)
    return logits[:, 0], {"prefix": new_prefix, "blocks": blk_caches}


def decode_step_paged(params: Pytree, cfg: ModelConfig, tokens, caches,
                      tables, positions, active):
    """One batched decode step over the paged pools.

    tokens: (slots, 1); tables: (slots, max_blocks) — or a tuple of one
    such array per layer group (`layer_group_keys`); positions: (slots,) —
    PER-LANE absolute positions, so heterogeneous lanes decode in ONE call
    (the seed engine ran one call per distinct position); active: (slots,)
    bool — inactive lanes write to the null block and their logits are
    garbage the engine ignores.

    Returns (logits (slots, 1, vocab), caches).
    """
    x = _embed_tokens(params, cfg, tokens)
    new_prefix = []
    for kind, p, c in zip(cfg.prefix_pattern, params["prefix"], caches["prefix"]):
        x, c = _block_decode_paged(cfg, kind, p, x, c, tables, positions, active)
        new_prefix.append(c)

    shared = params.get("shared")

    def body(carry, xs):
        x = carry
        ws, cache = xs
        new_caches = {}
        for i, kind in enumerate(cfg.pattern):
            p = shared if kind.startswith("shared_attn") else ws[f"b{i}"]
            x, c_out = _block_decode_paged(cfg, kind, p, x, cache[f"b{i}"],
                                           tables, positions, active)
            new_caches[f"b{i}"] = c_out
        return x, new_caches

    x, blk_caches = jax.lax.scan(body, x, (params["blocks"], caches["blocks"]))
    logits = _logits_head(params, cfg, x)
    return logits, {"prefix": new_prefix, "blocks": blk_caches}


def verify_step_paged(params: Pytree, cfg: ModelConfig, tokens, caches,
                      tables, positions, active, nvalid):
    """Batched speculative-verify step: score S = draft_len+1 tokens per
    lane in ONE forward pass over the paged pools — the GPP amortization
    move for decode, where the streamed weight working set otherwise buys
    a single token per lane.

    tokens: (slots, S) — row = [last produced token, draft_1..draft_k,
      pads]; tables/positions/active as in `decode_step_paged` (positions
      are per-lane START positions — query row s sits at positions[b]+s);
    nvalid: (slots,) int32 — real tokens per lane (1 + its draft length).
      Rows past nvalid write null block 0 and yield garbage logits the
      engine never reads, so this ONE (slots, S) shape serves every
      draft-length / acceptance pattern — the third and final jitted step
      shape next to prefill_chunk and decode_step_paged.

    Returns (logits (slots, S, vocab), caches): logits[b, i] scores the
    token AFTER tokens[b, i], exactly what acceptance sampling compares
    against draft_{i+1}.
    """
    x = _embed_tokens(params, cfg, tokens)
    new_prefix = []
    for kind, p, c in zip(cfg.prefix_pattern, params["prefix"], caches["prefix"]):
        x, c = _block_verify_paged(cfg, kind, p, x, c, tables, positions,
                                   active, nvalid)
        new_prefix.append(c)

    shared = params.get("shared")

    def body(carry, xs):
        x = carry
        ws, cache = xs
        new_caches = {}
        for i, kind in enumerate(cfg.pattern):
            p = shared if kind.startswith("shared_attn") else ws[f"b{i}"]
            x, c_out = _block_verify_paged(cfg, kind, p, x, cache[f"b{i}"],
                                           tables, positions, active, nvalid)
            new_caches[f"b{i}"] = c_out
        return x, new_caches

    x, blk_caches = jax.lax.scan(body, x, (params["blocks"], caches["blocks"]))
    logits = _logits_head(params, cfg, x)
    return logits, {"prefix": new_prefix, "blocks": blk_caches}


def prefill(params: Pytree, cfg: ModelConfig, batch: dict, max_len: int,
            mesh=None, act_pspec=None):
    """Process the prompt; returns (last-position logits, caches)."""
    if cfg.input_mode == "tokens":
        x = _embed_tokens(params, cfg, batch["tokens"])
    else:
        x = batch["embeds"].astype(cfg.jdtype)
    x = _wsc(x, act_pspec, mesh)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    enc = batch.get("enc")
    if enc is not None:
        enc = enc.astype(cfg.jdtype)

    caches = {"prefix": [], "blocks": {}}
    for kind, p in zip(cfg.prefix_pattern, params["prefix"]):
        x, c = _block_prefill(cfg, kind, p, x, positions, enc, max_len)
        caches["prefix"].append(c)

    shared = params.get("shared")

    def body(carry, ws):
        x = carry
        new_caches = {}
        for i, kind in enumerate(cfg.pattern):
            p = shared if kind.startswith("shared_attn") else ws[f"b{i}"]
            x, c = _block_prefill(cfg, kind, p, x, positions, enc, max_len)
            if c is not None:
                new_caches[f"b{i}"] = c
        return x, new_caches

    x, blk_caches = jax.lax.scan(body, x, params["blocks"])
    caches["blocks"] = blk_caches

    logits = _logits_head(params, cfg, x[:, -1:])
    return logits, caches


def decode_step(params: Pytree, cfg: ModelConfig, tokens_or_embeds, caches, pos,
                enc=None):
    """One decode step.  tokens: (B, 1) ints (or (B,1,D) embeds).  pos: traced
    scalar — absolute position of the new token."""
    if cfg.input_mode == "tokens":
        x = _embed_tokens(params, cfg, tokens_or_embeds)
    else:
        x = tokens_or_embeds.astype(cfg.jdtype)
    if enc is not None:
        enc = enc.astype(cfg.jdtype)

    new_prefix = []
    for kind, p, c in zip(cfg.prefix_pattern, params["prefix"], caches["prefix"]):
        x, c = _block_decode(cfg, kind, p, x, c, pos, enc)
        new_prefix.append(c)

    shared = params.get("shared")

    def body(carry, xs):
        x = carry
        ws, cache = xs
        new_caches = {}
        for i, kind in enumerate(cfg.pattern):
            p = shared if kind.startswith("shared_attn") else ws[f"b{i}"]
            c_in = cache.get(f"b{i}")
            x, c_out = _block_decode(cfg, kind, p, x, c_in, pos, enc)
            if c_out is not None:
                new_caches[f"b{i}"] = c_out
        return x, new_caches

    x, blk_caches = jax.lax.scan(body, x, (params["blocks"], caches["blocks"]))

    logits = _logits_head(params, cfg, x)
    return logits, {"prefix": new_prefix, "blocks": blk_caches}
