"""Attention variants: GQA (opt. QKV bias / sliding window / local:global),
MLA (DeepSeek-style latent compression), and cross-attention (VLM).

Cache layouts:
  full window   k/v: (B, S_max, KVH, hd), positions filled [0, pos)
  sliding (SWA) k/v: (B, W, KVH, hd) ring buffer indexed pos % W
  MLA           c_kv: (B, S_max, kv_lora), k_rope: (B, S_max, rope_dim)
                — the compressed-latent cache is the memory win.

All attention math accumulates in f32.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.kernels.ops import dense, paged_attn, resolve_paged_attn_mode
from repro.models.layers import sds, rope

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float = 1e4
    window: int | None = None          # sliding-window size (None = full)
    # MLA
    kv_lora_rank: int | None = None
    q_lora_rank: int | None = None
    rope_head_dim: int = 64
    dtype: object = jnp.bfloat16
    # kernels.ops.dense routing for every projection (cfg.dense_kernel):
    # "auto" streams big weights through the GPP Pallas kernel on TPU and
    # falls back to the bit-identical jnp path elsewhere
    dense_mode: str = "auto"
    # kernels.ops.paged_attn routing for the paged serving read path
    # (cfg.paged_attn_kernel): "ref" keeps the gather+_sdpa math below,
    # "pallas"/"interpret" stream KV blocks through the VMEM-ring kernel,
    # "auto" picks pallas on TPU and ref elsewhere
    paged_mode: str = "auto"

    @property
    def is_mla(self) -> bool:
        return self.kv_lora_rank is not None


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def attn_specs(c: AttnConfig):
    if c.is_mla:
        nope = c.head_dim
        sp = {
            "w_dkv": sds((c.d_model, c.kv_lora_rank + c.rope_head_dim), c.dtype),
            "w_uk": sds((c.kv_lora_rank, c.num_heads, nope), c.dtype),
            "w_uv": sds((c.kv_lora_rank, c.num_heads, nope), c.dtype),
            "w_o": sds((c.num_heads, nope, c.d_model), c.dtype),
            "kv_norm": sds((c.kv_lora_rank,), c.dtype),
        }
        if c.q_lora_rank:
            sp["w_dq"] = sds((c.d_model, c.q_lora_rank), c.dtype)
            sp["w_uq"] = sds((c.q_lora_rank, c.num_heads, nope + c.rope_head_dim), c.dtype)
            sp["q_norm"] = sds((c.q_lora_rank,), c.dtype)
        else:
            sp["w_q"] = sds((c.d_model, c.num_heads, nope + c.rope_head_dim), c.dtype)
        return sp
    sp = {
        "w_q": sds((c.d_model, c.num_heads, c.head_dim), c.dtype),
        "w_k": sds((c.d_model, c.num_kv_heads, c.head_dim), c.dtype),
        "w_v": sds((c.d_model, c.num_kv_heads, c.head_dim), c.dtype),
        "w_o": sds((c.num_heads, c.head_dim, c.d_model), c.dtype),
    }
    if c.qkv_bias:
        sp["b_q"] = sds((c.num_heads, c.head_dim), c.dtype)
        sp["b_k"] = sds((c.num_kv_heads, c.head_dim), c.dtype)
        sp["b_v"] = sds((c.num_kv_heads, c.head_dim), c.dtype)
    return sp


def cross_attn_specs(c: AttnConfig):
    """Cross-attention (queries from text, K/V from encoder embeddings)."""
    return {
        "w_q": sds((c.d_model, c.num_heads, c.head_dim), c.dtype),
        "w_k": sds((c.d_model, c.num_kv_heads, c.head_dim), c.dtype),
        "w_v": sds((c.d_model, c.num_kv_heads, c.head_dim), c.dtype),
        "w_o": sds((c.num_heads, c.head_dim, c.d_model), c.dtype),
        "q_norm": sds((c.head_dim,), c.dtype),
        "k_norm": sds((c.head_dim,), c.dtype),
    }


def cache_specs(c: AttnConfig, batch: int, max_len: int):
    """KV-cache ShapeDtypeStructs for decode."""
    if c.is_mla:
        return {
            "c_kv": sds((batch, max_len, c.kv_lora_rank), c.dtype),
            "k_rope": sds((batch, max_len, c.rope_head_dim), c.dtype),
        }
    span = min(max_len, c.window) if c.window else max_len
    return {
        "k": sds((batch, span, c.num_kv_heads, c.head_dim), c.dtype),
        "v": sds((batch, span, c.num_kv_heads, c.head_dim), c.dtype),
    }


# ---------------------------------------------------------------------------
# core attention math
# ---------------------------------------------------------------------------

def _sdpa(q, k, v, mask, scale):
    """q: (B,S,H,hd) k/v: (B,T,KVH,hd) mask: (B,S,T) or (S,T) broadcastable.

    k/v stay in their storage dtype (bf16) with f32 ACCUMULATION via
    preferred_element_type — an .astype(f32) on a 32k-token KV cache would
    materialize (and reshard) a full-size f32 copy.  probs are cast back to
    the storage dtype for the PV matmul (standard flash-kernel practice)."""
    B, S, H, hd = q.shape
    KVH = k.shape[2]
    rep = H // KVH
    qr = (q.astype(jnp.float32) * scale).astype(k.dtype)
    qr = qr.reshape(B, S, KVH, rep, hd)
    logits = jnp.einsum("bsgrh,btgh->bgrst", qr, k,
                        preferred_element_type=jnp.float32)
    logits = jnp.where(mask[:, None, None] if mask.ndim == 3 else mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrst,btgh->bsgrh", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    # v's head dim may differ from q/k's (MLA: values are nope-only)
    return out.reshape(B, S, H, v.shape[-1]).astype(q.dtype)


def causal_mask(S: int, T: int, q_offset: int = 0, window: int | None = None):
    """(S, T) mask: query i (global pos q_offset+i) sees keys j <= pos, and
    within `window` if set.  q_offset may be traced (chunked attention)."""
    qpos = q_offset + jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    m = kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m


def _ambient_constraint(x, spec):
    """with_sharding_constraint against the ambient mesh, if one is set and
    covers the named axes (no-op on mesh-less CPU test runs)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty:
            return x
        names = set(mesh.axis_names)
        if not all(a is None or a in names for a in spec):
            return x
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:  # noqa: BLE001 — constraints are an optimization only
        return x


def _tp_size() -> int:
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty:
            return 1
        return mesh.shape.get("model", 1)
    except Exception:  # noqa: BLE001
        return 1


ATTN_Q_CHUNK = 512  # query-block size for memory-efficient attention
ATTN_KV_CHUNK = 1024  # key-block size for context-parallel attention


def _sdpa_chunked(q, k, v, scale, *, window=None, chunk=ATTN_Q_CHUNK):
    """Causal attention with query blocking: scores for one (chunk x T) block
    live at a time and are rematerialized in backward — peak memory
    B*H*chunk*T*4 bytes instead of B*H*S*T*4 (the 17 GiB -> 2 GiB difference
    at seq 4k/32k).  TPU-adaptation note (DESIGN.md): this is the pure-XLA
    stand-in for a flash-attention kernel; the blocks are VMEM-sized."""
    B, S, H, hd = q.shape
    if S <= chunk or S % chunk:
        return _sdpa(q, k, v, causal_mask(S, k.shape[1], 0, window), scale)
    nq = S // chunk
    qs = q.reshape(B, nq, chunk, H, hd).swapaxes(0, 1)  # (nq, B, qc, H, hd)

    @jax.checkpoint
    def body(carry, inp):
        qc, i = inp
        mask = causal_mask(chunk, k.shape[1], i * chunk, window)
        return carry, _sdpa(qc, k, v, mask, scale)

    _, outs = jax.lax.scan(body, jnp.zeros((), jnp.int32),
                           (qs, jnp.arange(nq)))
    # out head dim follows v (MLA values are nope-only, narrower than q)
    return outs.swapaxes(0, 1).reshape(B, S, H, v.shape[-1])


def _sdpa_kv_chunked(q, k, v, scale, *, window=None, chunk=ATTN_KV_CHUNK,
                     q_offset=0, varying_axes=None):
    """Online-softmax attention scanning KEY blocks.  q rows may be a
    sequence-shard (context parallelism): `q_offset` gives their global
    position for the causal mask.  Peak memory is one (S_local x chunk)
    block of logits."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    KVH = k.shape[2]
    rep = H // KVH
    vd = v.shape[-1]
    if T % chunk or T <= chunk:
        return _sdpa(q, k, v, causal_mask(S, T, q_offset, window), scale)
    nt = T // chunk
    qr = (q.astype(jnp.float32) * scale).astype(k.dtype).reshape(B, S, KVH, rep, hd)
    ks = k.reshape(B, nt, chunk, KVH, hd).swapaxes(0, 1)
    vs = v.reshape(B, nt, chunk, KVH, vd).swapaxes(0, 1)

    m0 = jnp.full((B, KVH, rep, S), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KVH, rep, S), jnp.float32)
    a0 = jnp.zeros((B, S, KVH, rep, vd), jnp.float32)
    if varying_axes:
        # under shard_map the carry must match the body's varying-axis type
        m0, l0, a0 = (jax.lax.pcast(t, tuple(varying_axes), to="varying")
                      for t in (m0, l0, a0))

    @jax.checkpoint
    def body(carry, inp):
        m, l, acc = carry
        kc, vc, j = inp
        logits = jnp.einsum("bsgrh,btgh->bgrst", qr, kc,
                            preferred_element_type=jnp.float32)
        # query i is global position q_offset+i; keys are at j*chunk + t
        mask = causal_mask(S, chunk, q_offset - j * chunk, window)
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        # guard: fully-masked rows keep m=-inf; use a safe max for exps
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(logits - m_safe[..., None])
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = (acc * corr.transpose(0, 3, 1, 2)[..., None]
                   + jnp.einsum("bgrst,btgh->bsgrh", p.astype(vc.dtype), vc,
                                preferred_element_type=jnp.float32))
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (ks, vs, jnp.arange(nt)))
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return out.reshape(B, S, H, vd).astype(q.dtype)


def _attend(q, k, v, scale, *, window=None):
    """Dispatch: context-parallel attention (shard_map: q sequence-sharded
    over the model axis, k/v replicated per data shard, masks on global
    positions) when heads don't divide the TP axis; q-chunked otherwise.

    shard_map (not sharding constraints) because the SPMD partitioner is
    free to re-shard einsum internals mid-graph — on a 28-head model it
    chooses head_dim contraction sharding and all-reduces every logits
    block (~7 GiB x layers x chunks).  Manual mapping pins the layout."""
    H = q.shape[2]
    tp = _tp_size()
    if tp > 1 and H % tp and q.shape[1] % tp == 0 and q.shape[1] > 1:
        from jax.sharding import PartitionSpec as P
        mesh = jax.sharding.get_abstract_mesh()
        dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        B = q.shape[0]
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        bspec = dp if (dp and B % dp_size == 0) else None
        S_local = q.shape[1] // tp

        def local(qb, kb, vb):
            idx = jax.lax.axis_index("model")
            return _sdpa_kv_chunked(qb, kb, vb, scale, window=window,
                                    q_offset=idx * S_local,
                                    varying_axes=mesh.axis_names)

        return jax.shard_map(
            local,
            mesh=mesh,
            in_specs=(P(bspec, "model", None, None),
                      P(bspec, None, None, None),
                      P(bspec, None, None, None)),
            out_specs=P(bspec, "model", None, None),
        )(q, k, v)
    return _sdpa_chunked(q, k, v, scale, window=window)


# ---------------------------------------------------------------------------
# GQA (covers MHA, SWA, local/global via `window`)
# ---------------------------------------------------------------------------

def gqa_project_qkv(p, c: AttnConfig, x, positions):
    """q/k/v projections through `kernels.ops.dense` (bias fused into the
    streaming epilogue); "ref" routing reproduces the einsum math exactly."""
    bq = p["b_q"] if c.qkv_bias else None
    bk = p["b_k"] if c.qkv_bias else None
    bv = p["b_v"] if c.qkv_bias else None
    q = dense(x, p["w_q"], bias=bq, mode=c.dense_mode)
    k = dense(x, p["w_k"], bias=bk, mode=c.dense_mode)
    v = dense(x, p["w_v"], bias=bv, mode=c.dense_mode)
    q = rope(q, positions, c.rope_theta)
    k = rope(k, positions, c.rope_theta)
    return q, k, v


def gqa_forward(p, c: AttnConfig, x, positions):
    """Training/prefill self-attention (causal, optional window)."""
    B, S, _ = x.shape
    q, k, v = gqa_project_qkv(p, c, x, positions)
    out = _attend(q, k, v, 1.0 / math.sqrt(c.head_dim), window=c.window)
    return dense(out, p["w_o"], mode=c.dense_mode, contract_dims=2)


def gqa_prefill(p, c: AttnConfig, x, positions, max_len: int):
    """Prefill: returns (out, cache) with cache laid out for decode."""
    B, S, _ = x.shape
    q, k, v = gqa_project_qkv(p, c, x, positions)
    out = _attend(q, k, v, 1.0 / math.sqrt(c.head_dim), window=c.window)
    span = min(max_len, c.window) if c.window else max_len
    kc = jnp.zeros((B, span, c.num_kv_heads, c.head_dim), k.dtype)
    vc = jnp.zeros_like(kc)
    if c.window and S > span:
        k_tail, v_tail = k[:, -span:], v[:, -span:]
        # ring layout: slot = pos % span
        slots = (positions[:, -span:]) % span
        kc = kc.at[jnp.arange(B)[:, None], slots].set(k_tail)
        vc = vc.at[jnp.arange(B)[:, None], slots].set(v_tail)
    else:
        kc = jax.lax.dynamic_update_slice(kc, k[:, : min(S, span)], (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v[:, : min(S, span)], (0, 0, 0, 0))
    return (dense(out, p["w_o"], mode=c.dense_mode, contract_dims=2),
            {"k": kc, "v": vc})


def gqa_decode(p, c: AttnConfig, x, cache, pos):
    """One-token decode. x: (B, 1, D); pos: scalar current position."""
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = gqa_project_qkv(p, c, x, positions)
    span = cache["k"].shape[1]
    slot = pos % span if c.window else pos
    kc = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    kpos_abs = jnp.arange(span)
    if c.window:
        # ring: entry j holds absolute position p' with p' % span == j,
        # p' in (pos-span, pos]
        kpos_abs = pos - ((pos - kpos_abs) % span)
        valid = (kpos_abs >= 0) & (kpos_abs >= pos - (c.window - 1))
    else:
        valid = kpos_abs <= pos
    mask = valid[None, None, :]  # (1,1,span) -> broadcast (B,1,span)
    mask = jnp.broadcast_to(mask, (B, 1, span))
    out = _sdpa(q, kc, vc, mask, 1.0 / math.sqrt(c.head_dim))
    return (dense(out, p["w_o"], mode=c.dense_mode, contract_dims=2),
            {"k": kc, "v": vc})


# ---------------------------------------------------------------------------
# paged KV (block-pool) read/write — serving subsystem
# ---------------------------------------------------------------------------
#
# Pool layouts (no per-lane batch dim; capacity shared across lanes):
#   full/window k/v: (num_blocks, block_size, KVH, hd)
#   MLA            : c_kv (num_blocks, block_size, kv_lora),
#                    k_rope (num_blocks, block_size, rope_dim)
# A lane's logical block b (absolute positions [b*bs, (b+1)*bs)) lives at
# physical block `tables[lane, b]`; block 0 is the reserved null/scratch
# block (unmapped reads land there and are masked, inactive writes are
# parked there).  Window layers use the same absolute-slot layout as full
# attention (no ring) — the window is enforced by the mask, so the existing
# `causal_mask` / `_sdpa` kernels carry the paged path unchanged.


def paged_cache_specs(c: AttnConfig, num_blocks: int, block_size: int):
    """Pool ShapeDtypeStructs for one attention layer (shared across lanes)."""
    if c.is_mla:
        return {
            "c_kv": sds((num_blocks, block_size, c.kv_lora_rank), c.dtype),
            "k_rope": sds((num_blocks, block_size, c.rope_head_dim), c.dtype),
        }
    return {
        "k": sds((num_blocks, block_size, c.num_kv_heads, c.head_dim), c.dtype),
        "v": sds((num_blocks, block_size, c.num_kv_heads, c.head_dim), c.dtype),
    }


def _paged_gather(pool, tables):
    """Gather a pool through block tables: (nb, bs, ...) x (B, MB) ->
    (B, MB*bs, ...) — each lane's logical KV sequence, position-ordered."""
    g = pool[tables]                                   # (B, MB, bs, ...)
    return g.reshape(tables.shape[0], -1, *pool.shape[2:])


def _paged_write_span(pool, table_row, start_pos, vals):
    """Write `vals` (1, S, ...) at absolute positions [start_pos, start_pos+S)
    of the lane whose table row is `table_row` (1, MB) — one (block, offset)
    scatter per token, so start_pos may be ANY token index.  Non-alignment
    arises from prefix-cache hits: prefill resumes at the matched token
    count, mid-block when a shared tail block was forked (the lane owns
    every block the span touches — shared blocks all sit below start_pos;
    the engine asserts this via `PagedKVCache.assert_writable`)."""
    bs = pool.shape[1]
    S = vals.shape[1]
    pos = start_pos + jnp.arange(S, dtype=jnp.int32)
    blk = jnp.take(table_row[0], pos // bs)
    return pool.at[blk, pos % bs].set(vals[0])


def _paged_write_token(pool, tables, positions, active, vals):
    """Scatter one token per lane: vals (B, ...) at each lane's `positions`.
    Inactive lanes are parked on null block 0 (their table lookup may be
    stale), so one fixed-shape scatter serves any active subset."""
    bs = pool.shape[1]
    B = tables.shape[0]
    blk = jnp.take_along_axis(tables, (positions // bs)[:, None], axis=1)[:, 0]
    blk = jnp.where(active, blk, 0)
    off = jnp.where(active, positions % bs, 0)
    return pool.at[blk, off].set(vals)


def _paged_write_multi(pool, tables, positions, active, nvalid, vals):
    """Scatter S tokens per lane: vals (B, S, ...) land at absolute
    positions `positions[b] + s` for s < nvalid[b] (speculative verify
    bursts).  Rows past a lane's real token count — and whole inactive
    lanes — are parked on null block 0, so ONE fixed (B, S) scatter shape
    serves every draft-length / acceptance pattern."""
    bs = pool.shape[1]
    S = vals.shape[1]
    pos = positions[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    valid = active[:, None] & (jnp.arange(S)[None, :] < nvalid[:, None])
    blk = jnp.take_along_axis(tables, jnp.where(valid, pos // bs, 0), axis=1)
    blk = jnp.where(valid, blk, 0)
    off = jnp.where(valid, pos % bs, 0)
    return pool.at[blk, off].set(vals)


def paged_mask(positions, T: int, *, S: int = 1, window: "int | None" = None):
    """(B, S, T) decode/verify mask over a gathered pool: key slot j holds
    absolute position j; query row s of lane b sits at positions[b] + s —
    valid iff j <= that (and within `window`).  S=1 is plain decode."""
    kpos = jnp.arange(T)[None, None, :]
    qpos = positions[:, None, None] + jnp.arange(S)[None, :, None]
    m = kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m


def _gqa_paged_attend(c: AttnConfig, q, kc, vc, tables, positions):
    """Dispatch the paged GQA read through `kernels.ops.paged_attn`: "ref"
    gathers each lane's logical sequence through the tables and runs the
    exact `_sdpa` math (`kernels.ref.paged_attn_ref`, the pre-kernel path
    bit-for-bit); the kernel modes stream KV blocks through the VMEM ring
    instead — the gathered (B, MB*bs, ...) sequence is never formed."""
    return paged_attn(q, kc, vc, tables, positions,
                      num_kv_heads=c.num_kv_heads,
                      scale=1.0 / math.sqrt(c.head_dim),
                      window=c.window, mode=c.paged_mode)


def gqa_prefill_paged(p, c: AttnConfig, x, cache, table_row, start_pos):
    """One prefill chunk (B=1): project, write whole blocks, attend over the
    lane's blocks.  x: (1, S, D), start_pos: traced block-aligned scalar."""
    S = x.shape[1]
    positions = start_pos + jnp.arange(S, dtype=jnp.int32)[None]
    q, k, v = gqa_project_qkv(p, c, x, positions)
    kc = _paged_write_span(cache["k"], table_row, start_pos, k)
    vc = _paged_write_span(cache["v"], table_row, start_pos, v)
    out = _gqa_paged_attend(c, q, kc, vc, table_row,
                            jnp.reshape(start_pos, (1,)).astype(jnp.int32))
    return (dense(out, p["w_o"], mode=c.dense_mode, contract_dims=2),
            {"k": kc, "v": vc})


def gqa_decode_paged(p, c: AttnConfig, x, cache, tables, positions, active):
    """One-token decode across lanes at heterogeneous positions.
    x: (B, 1, D); tables: (B, MB); positions: (B,); active: (B,) bool."""
    q, k, v = gqa_project_qkv(p, c, x, positions[:, None])
    kc = _paged_write_token(cache["k"], tables, positions, active, k[:, 0])
    vc = _paged_write_token(cache["v"], tables, positions, active, v[:, 0])
    out = _gqa_paged_attend(c, q, kc, vc, tables, positions)
    return (dense(out, p["w_o"], mode=c.dense_mode, contract_dims=2),
            {"k": kc, "v": vc})


def gqa_verify_paged(p, c: AttnConfig, x, cache, tables, positions, active,
                     nvalid):
    """Speculative verify: S = draft_len+1 tokens per lane in ONE forward
    pass, so the streamed weight working set amortizes over up to S tokens
    per lane instead of 1 (the GPP low-utilization fix for decode).
    x: (B, S, D); positions: (B,) per-lane START positions; nvalid: (B,)
    real tokens per lane — rows past it write null block 0 and their
    logits are ignored by the engine.  The paged-attention read path is
    position-exact for S > 1 already (query row s sits at positions[b]+s),
    so verify rides the same kernel as decode."""
    S = x.shape[1]
    pos2 = positions[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    q, k, v = gqa_project_qkv(p, c, x, pos2)
    kc = _paged_write_multi(cache["k"], tables, positions, active, nvalid, k)
    vc = _paged_write_multi(cache["v"], tables, positions, active, nvalid, v)
    out = _gqa_paged_attend(c, q, kc, vc, tables, positions)
    return (dense(out, p["w_o"], mode=c.dense_mode, contract_dims=2),
            {"k": kc, "v": vc})


def _mla_paged_attend(p, c: AttnConfig, q, ckv, kr, tables, positions,
                      *, prefill: bool):
    """Dispatch the paged MLA read.  "ref" gathers the latent pools and runs
    the unmodified `_mla_attend` (up-project k/v, then `_sdpa`).  The kernel
    modes use the weight-absorbed decode form instead: q is folded through
    w_uk so logits contract directly against the streamed c_kv/k_rope blocks
    (MQA over the latent), and the latent-space output is up-projected
    through w_uv after the kernel — the same math reassociated, with the
    compressed latent (not full K/V) the only thing that crosses HBM."""
    mode = resolve_paged_attn_mode(c.paged_mode, q, ckv, kr)
    if mode == "ref":
        ckv_seq = _paged_gather(ckv, tables)
        kr_seq = _paged_gather(kr, tables)
        if prefill:
            mask = causal_mask(q.shape[1], ckv_seq.shape[1], positions[0])
        else:
            mask = paged_mask(positions, ckv_seq.shape[1], S=q.shape[1])
        return _mla_attend(p, c, q, ckv_seq, kr_seq, mask)
    nope = c.head_dim
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    # absorb w_uk into q: q_abs[h] . c_kv[t] == q_nope[h] . k_nope[t, h]
    q_abs = jnp.einsum("bshn,rhn->bshr", q_nope, p["w_uk"]).astype(q.dtype)
    q_eff = jnp.concatenate([q_abs, q_rope], axis=-1)
    out_lat = paged_attn(q_eff, ckv, kr, tables, positions,
                         num_kv_heads=1, mla=True,
                         scale=1.0 / math.sqrt(nope + c.rope_head_dim),
                         mode=mode)
    out = jnp.einsum("bshr,rhn->bshn", out_lat, p["w_uv"]).astype(q.dtype)
    return dense(out, p["w_o"], mode=c.dense_mode, contract_dims=2)


def mla_prefill_paged(p, c: AttnConfig, x, cache, table_row, start_pos):
    """MLA prefill chunk: the compressed latent (not full K/V) is what pages
    through the pool — the paper's capacity argument compounded."""
    S = x.shape[1]
    positions = start_pos + jnp.arange(S, dtype=jnp.int32)[None]
    q = _mla_q(p, c, x, positions)
    c_kv, k_rope = _mla_latent(p, c, x, positions)
    ckv = _paged_write_span(cache["c_kv"], table_row, start_pos, c_kv)
    kr = _paged_write_span(cache["k_rope"], table_row, start_pos, k_rope)
    out = _mla_paged_attend(p, c, q, ckv, kr, table_row,
                            jnp.reshape(start_pos, (1,)).astype(jnp.int32),
                            prefill=True)
    return out, {"c_kv": ckv, "k_rope": kr}


def mla_decode_paged(p, c: AttnConfig, x, cache, tables, positions, active):
    q = _mla_q(p, c, x, positions[:, None])
    c_kv_new, k_rope_new = _mla_latent(p, c, x, positions[:, None])
    ckv = _paged_write_token(cache["c_kv"], tables, positions, active,
                             c_kv_new[:, 0])
    kr = _paged_write_token(cache["k_rope"], tables, positions, active,
                            k_rope_new[:, 0])
    out = _mla_paged_attend(p, c, q, ckv, kr, tables, positions,
                            prefill=False)
    return out, {"c_kv": ckv, "k_rope": kr}


def mla_verify_paged(p, c: AttnConfig, x, cache, tables, positions, active,
                     nvalid):
    """Speculative verify over the compressed-latent pools — see
    `gqa_verify_paged` for the contract; the per-row position vector
    (positions[b] + s) drives both rope and the paged mask."""
    S = x.shape[1]
    pos2 = positions[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    q = _mla_q(p, c, x, pos2)
    c_kv_new, k_rope_new = _mla_latent(p, c, x, pos2)
    ckv = _paged_write_multi(cache["c_kv"], tables, positions, active,
                             nvalid, c_kv_new)
    kr = _paged_write_multi(cache["k_rope"], tables, positions, active,
                            nvalid, k_rope_new)
    out = _mla_paged_attend(p, c, q, ckv, kr, tables, positions,
                            prefill=False)
    return out, {"c_kv": ckv, "k_rope": kr}


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention)
# ---------------------------------------------------------------------------

def _mla_q(p, c: AttnConfig, x, positions):
    from repro.models.layers import rmsnorm
    nope = c.head_dim
    if c.q_lora_rank:
        cq = rmsnorm({"scale": p["q_norm"]}, dense(x, p["w_dq"], mode=c.dense_mode))
        q = dense(cq, p["w_uq"], mode=c.dense_mode)
    else:
        q = dense(x, p["w_q"], mode=c.dense_mode)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, positions, c.rope_theta)
    return jnp.concatenate([q_nope, q_rope], axis=-1)


def _mla_latent(p, c: AttnConfig, x, positions):
    from repro.models.layers import rmsnorm
    d = dense(x, p["w_dkv"], mode=c.dense_mode)
    c_kv, k_rope = d[..., : c.kv_lora_rank], d[..., c.kv_lora_rank:]
    c_kv = rmsnorm({"scale": p["kv_norm"]}, c_kv)
    k_rope = rope(k_rope[..., None, :], positions, c.rope_theta)[..., 0, :]
    return c_kv, k_rope


def _mla_attend(p, c: AttnConfig, q, c_kv, k_rope, mask):
    nope = c.head_dim
    k_nope = dense(c_kv, p["w_uk"], mode=c.dense_mode)
    v = dense(c_kv, p["w_uv"], mode=c.dense_mode)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (*k_nope.shape[:3], c.rope_head_dim))], axis=-1
    )
    out = _sdpa(q, k, v, mask, 1.0 / math.sqrt(nope + c.rope_head_dim))
    out = out[..., :nope]  # v has nope dims; _sdpa padded? no: v dims = nope
    return dense(out, p["w_o"], mode=c.dense_mode, contract_dims=2)


def mla_forward(p, c: AttnConfig, x, positions):
    B, S, _ = x.shape
    q = _mla_q(p, c, x, positions)
    c_kv, k_rope = _mla_latent(p, c, x, positions)
    nope = c.head_dim
    k_nope = dense(c_kv, p["w_uk"], mode=c.dense_mode)
    v = dense(c_kv, p["w_uv"], mode=c.dense_mode)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (*k_nope.shape[:3], c.rope_head_dim))], axis=-1)
    out = _sdpa_chunked(q, k, v, 1.0 / math.sqrt(nope + c.rope_head_dim))
    return dense(out, p["w_o"], mode=c.dense_mode, contract_dims=2)


def mla_prefill(p, c: AttnConfig, x, positions, max_len: int):
    B, S, _ = x.shape
    out = mla_forward(p, c, x, positions)
    c_kv, k_rope = _mla_latent(p, c, x, positions)
    ckv_buf = jnp.zeros((B, max_len, c.kv_lora_rank), c_kv.dtype)
    kr_buf = jnp.zeros((B, max_len, c.rope_head_dim), k_rope.dtype)
    ckv_buf = jax.lax.dynamic_update_slice(ckv_buf, c_kv[:, :max_len], (0, 0, 0))
    kr_buf = jax.lax.dynamic_update_slice(kr_buf, k_rope[:, :max_len], (0, 0, 0))
    return out, {"c_kv": ckv_buf, "k_rope": kr_buf}


def mla_decode(p, c: AttnConfig, x, cache, pos):
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q = _mla_q(p, c, x, positions)
    c_kv_new, k_rope_new = _mla_latent(p, c, x, positions)
    ckv = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv_new, (0, pos, 0))
    kr = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope_new, (0, pos, 0))
    T = ckv.shape[1]
    mask = jnp.broadcast_to((jnp.arange(T) <= pos)[None, None, :], (B, 1, T))
    out = _mla_attend(p, c, q, ckv, kr, mask)
    return out, {"c_kv": ckv, "k_rope": kr}


# ---------------------------------------------------------------------------
# cross-attention (text queries over encoder embeddings)
# ---------------------------------------------------------------------------

def cross_attn_forward(p, c: AttnConfig, x, enc):
    """x: (B, S, D) text; enc: (B, T, D) patch/frame embeddings (stubbed
    modality frontend).  No causal mask; no cache growth during decode."""
    from repro.models.layers import rmsnorm
    q = dense(x, p["w_q"], mode=c.dense_mode)
    k = dense(enc, p["w_k"], mode=c.dense_mode)
    v = dense(enc, p["w_v"], mode=c.dense_mode)
    q = rmsnorm({"scale": p["q_norm"]}, q)
    k = rmsnorm({"scale": p["k_norm"]}, k)
    B, S = x.shape[:2]
    T = enc.shape[1]
    mask = jnp.ones((B, S, T), bool)
    out = _sdpa(q, k, v, mask, 1.0 / math.sqrt(c.head_dim))
    return dense(out, p["w_o"], mode=c.dense_mode, contract_dims=2)
