"""xLSTM blocks: mLSTM (matrix-memory) and sLSTM (scalar-memory with
exponential gating), per arXiv:2405.04517, adapted to TPU-friendly JAX.

mLSTM state: per-head matrix memory M (hd x hd), normalizer n (hd), max-gate
m (scalar) — decode is O(1), which is why xlstm runs the long_500k cell.
Sequence mode uses a chunkwise recurrence over an associative scan of the
gate products (log-depth), matching the recurrent semantics exactly.

sLSTM state: per-head scalar cell c, normalizer n, max-gate m.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels.ops import dense
from repro.models.layers import sds


@dataclasses.dataclass(frozen=True)
class XlstmConfig:
    d_model: int
    n_heads: int
    dtype: object = jnp.bfloat16
    dense_mode: str = "auto"   # kernels.ops.dense routing for all projections

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_specs(c: XlstmConfig):
    d, h, hd = c.d_model, c.n_heads, c.head_dim
    return {
        "w_q": sds((d, h, hd), c.dtype),
        "w_k": sds((d, h, hd), c.dtype),
        "w_v": sds((d, h, hd), c.dtype),
        "w_i": sds((d, h), c.dtype),       # input gate (exp)
        "w_f": sds((d, h), c.dtype),       # forget gate
        "b_i": sds((h,), jnp.float32),
        "b_f": sds((h,), jnp.float32),
        "w_o": sds((h, hd, d), c.dtype),
        "ogate": sds((d, d), c.dtype),
    }


def mlstm_state_specs(c: XlstmConfig, batch: int):
    h, hd = c.n_heads, c.head_dim
    return {
        "M": sds((batch, h, hd, hd), jnp.float32),
        "n": sds((batch, h, hd), jnp.float32),
        "m": sds((batch, h), jnp.float32),
    }


def _mlstm_gates(p, c: XlstmConfig, x):
    i = dense(x, p["w_i"], mode=c.dense_mode).astype(jnp.float32) + p["b_i"]
    f = dense(x, p["w_f"], mode=c.dense_mode).astype(jnp.float32) + p["b_f"]
    logf = -jax.nn.softplus(-f)           # log sigmoid(f): stable
    return i, logf


MLSTM_CHUNK = 256  # quadratic window kept VMEM-sized (TPU adaptation)


def _mlstm_qkv(p, c: XlstmConfig, x):
    hd = c.head_dim
    q = dense(x, p["w_q"], mode=c.dense_mode).astype(jnp.float32)
    k = dense(x, p["w_k"], mode=c.dense_mode).astype(jnp.float32) / (hd ** 0.5)
    v = dense(x, p["w_v"], mode=c.dense_mode).astype(jnp.float32)
    return q, k, v


def _mlstm_chunk_scan(p, c: XlstmConfig, x, state0):
    """Chunkwise-parallel mLSTM: exact recurrence across chunks, quadratic
    form inside each chunk.  Returns (hidden (B,S,h,hd) f32, final state)."""
    B, S, D = x.shape
    h, hd = c.n_heads, c.head_dim
    L = min(MLSTM_CHUNK, S)
    if S % L:
        raise ValueError(f"seq len {S} must be divisible by chunk {L}")
    nc = S // L
    q, k, v = _mlstm_qkv(p, c, x)
    i, logf = _mlstm_gates(p, c, x)       # (B,S,h)

    def reshape_c(t):  # (B,S,...) -> (nc,B,L,...)
        return t.reshape(B, nc, L, *t.shape[2:]).swapaxes(0, 1)

    qs, ks, vs, is_, lfs = map(reshape_c, (q, k, v, i, logf))

    def step(state, xs):
        qc, kc, vc, ic, lfc = xs          # (B,L,h,hd) / (B,L,h)
        M0, n0, m0 = state["M"], state["n"], state["m"]
        F = jnp.cumsum(lfc, axis=1)       # (B,L,h) log decay within chunk
        # intra-chunk: D_ts = F_t - F_s + i_s (s <= t)
        logits = F[:, :, None, :] - F[:, None, :, :] + ic[:, None, :, :]
        causal = jnp.tril(jnp.ones((L, L), bool))
        logits = jnp.where(causal[None, :, :, None], logits, -jnp.inf)
        m_intra = jnp.max(logits, axis=2)             # (B,L,h)
        log_inter = F + m0[:, None, :]                # state weight for query t
        m_t = jnp.maximum(m_intra, log_inter)
        dmat = jnp.exp(logits - m_t[:, :, None, :])   # (B,t,s,h)
        scores = jnp.einsum("bthk,bshk->btsh", qc, kc) * dmat
        w_inter = jnp.exp(log_inter - m_t)            # (B,L,h)
        num = (jnp.einsum("btsh,bshk->bthk", scores, vc)
               + w_inter[..., None] * jnp.einsum("bthk,bhkv->bthv", qc, M0))
        den = (jnp.einsum("btsh,bshk->bth", scores, kc)
               + w_inter * jnp.einsum("bthk,bhk->bth", qc, n0))
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
        hid = num / den[..., None]                    # (B,L,h,hd)
        # state update to end of chunk
        Fl = F[:, -1:, :]                             # (B,1,h)
        logw = Fl - F + ic                            # weight of step s
        m_new = jnp.maximum(Fl[:, 0] + m0, jnp.max(logw, axis=1))
        wst = jnp.exp(logw - m_new[:, None])
        M = (jnp.exp(Fl[:, 0] + m0 - m_new)[..., None, None] * M0
             + jnp.einsum("bsh,bshk,bshv->bhkv", wst, kc, vc))
        n = (jnp.exp(Fl[:, 0] + m0 - m_new)[..., None] * n0
             + jnp.einsum("bsh,bshk->bhk", wst, kc))
        return {"M": M, "n": n, "m": m_new}, hid

    state, hids = jax.lax.scan(step, state0, (qs, ks, vs, is_, lfs))
    hid = hids.swapaxes(0, 1).reshape(B, S, h, hd)
    return hid, state


def _mlstm_state0(c: XlstmConfig, B: int):
    h, hd = c.n_heads, c.head_dim
    return {
        "M": jnp.zeros((B, h, hd, hd), jnp.float32),
        "n": jnp.zeros((B, h, hd), jnp.float32),
        "m": jnp.full((B, h), -1e30, jnp.float32),
    }


def mlstm_forward(p, c: XlstmConfig, x: jnp.ndarray) -> jnp.ndarray:
    B = x.shape[0]
    hid, _ = _mlstm_chunk_scan(p, c, x, _mlstm_state0(c, B))
    o = dense(x, p["ogate"], activation="sigmoid", mode=c.dense_mode)
    y = dense(hid.astype(x.dtype), p["w_o"], mode=c.dense_mode, contract_dims=2)
    return y * o


def mlstm_prefill(p, c: XlstmConfig, x: jnp.ndarray):
    B = x.shape[0]
    hid, state = _mlstm_chunk_scan(p, c, x, _mlstm_state0(c, B))
    o = dense(x, p["ogate"], activation="sigmoid", mode=c.dense_mode)
    y = dense(hid.astype(x.dtype), p["w_o"], mode=c.dense_mode,
              contract_dims=2) * o
    return y, state


def mlstm_decode(p, c: XlstmConfig, x: jnp.ndarray, state):
    """One-step recurrence. x: (B,1,D)."""
    B = x.shape[0]
    h, hd = c.n_heads, c.head_dim
    q = dense(x[:, 0], p["w_q"], mode=c.dense_mode).astype(jnp.float32)
    k = dense(x[:, 0], p["w_k"], mode=c.dense_mode).astype(jnp.float32) / (hd ** 0.5)
    v = dense(x[:, 0], p["w_v"], mode=c.dense_mode).astype(jnp.float32)
    i, logf = _mlstm_gates(p, c, x[:, 0])
    m_new = jnp.maximum(logf + state["m"], i)
    fw = jnp.exp(logf + state["m"] - m_new)[..., None]
    iw = jnp.exp(i - m_new)[..., None]
    M = state["M"] * fw[..., None] + iw[..., None] * k[..., None] * v[..., None, :]
    n = state["n"] * fw + iw * k
    num = jnp.einsum("bhk,bhkv->bhv", q, M)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, n)), jnp.exp(-m_new))
    out = (num / den[..., None]).astype(x.dtype)
    o = dense(x[:, 0], p["ogate"], activation="sigmoid", mode=c.dense_mode)
    y = dense(out, p["w_o"], mode=c.dense_mode, contract_dims=2) * o
    return y[:, None], {"M": M, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_specs(c: XlstmConfig):
    d, h, hd = c.d_model, c.n_heads, c.head_dim
    return {
        "w_z": sds((d, d), c.dtype), "w_i": sds((d, h), c.dtype),
        "w_f": sds((d, h), c.dtype), "w_og": sds((d, d), c.dtype),
        "b_i": sds((h,), jnp.float32), "b_f": sds((h,), jnp.float32),
        "w_out": sds((d, d), c.dtype),
    }


def slstm_state_specs(c: XlstmConfig, batch: int):
    return {
        "c": sds((batch, c.n_heads, c.head_dim), jnp.float32),
        "n": sds((batch, c.n_heads), jnp.float32),
        "m": sds((batch, c.n_heads), jnp.float32),
    }


def _slstm_step(p, c: XlstmConfig, state, inputs):
    z_t, i_t, logf_t, _ = inputs
    m_new = jnp.maximum(logf_t + state["m"], i_t)
    fw = jnp.exp(logf_t + state["m"] - m_new)
    iw = jnp.exp(i_t - m_new)
    cell = state["c"] * fw[..., None] + iw[..., None] * z_t
    n = state["n"] * fw + iw
    h = cell / jnp.maximum(n, 1.0)[..., None]
    return {"c": cell, "n": n, "m": m_new}, h


def _slstm_inputs(p, c: XlstmConfig, x):
    B, S, D = x.shape
    z = jnp.tanh(dense(x, p["w_z"], mode=c.dense_mode).astype(jnp.float32)
                 ).reshape(B, S, c.n_heads, c.head_dim)
    i = dense(x, p["w_i"], mode=c.dense_mode).astype(jnp.float32) + p["b_i"]
    f = dense(x, p["w_f"], mode=c.dense_mode).astype(jnp.float32) + p["b_f"]
    logf = -jax.nn.softplus(-f)
    og = dense(x, p["w_og"], activation="sigmoid", mode=c.dense_mode)
    return z, i, logf, og


def slstm_forward(p, c: XlstmConfig, x: jnp.ndarray) -> jnp.ndarray:
    B, S, D = x.shape
    z, i, logf, og = _slstm_inputs(p, c, x)
    state0 = {
        "c": jnp.zeros((B, c.n_heads, c.head_dim), jnp.float32),
        "n": jnp.zeros((B, c.n_heads), jnp.float32),
        "m": jnp.full((B, c.n_heads), -1e30, jnp.float32),
    }

    def step(st, xs):
        return _slstm_step(p, c, st, xs)

    _, hs = jax.lax.scan(
        step, state0,
        (z.swapaxes(0, 1), i.swapaxes(0, 1), logf.swapaxes(0, 1),
         jnp.zeros((S, 1), jnp.float32)),
    )
    h = hs.swapaxes(0, 1).reshape(B, S, D).astype(x.dtype)
    return dense(h * og, p["w_out"], mode=c.dense_mode)


def slstm_prefill(p, c: XlstmConfig, x: jnp.ndarray):
    B, S, D = x.shape
    z, i, logf, og = _slstm_inputs(p, c, x)
    state0 = {
        "c": jnp.zeros((B, c.n_heads, c.head_dim), jnp.float32),
        "n": jnp.zeros((B, c.n_heads), jnp.float32),
        "m": jnp.full((B, c.n_heads), -1e30, jnp.float32),
    }

    def step(st, xs):
        return _slstm_step(p, c, st, xs)

    state, hs = jax.lax.scan(
        step, state0,
        (z.swapaxes(0, 1), i.swapaxes(0, 1), logf.swapaxes(0, 1),
         jnp.zeros((S, 1), jnp.float32)),
    )
    h = hs.swapaxes(0, 1).reshape(B, S, D).astype(x.dtype)
    return dense(h * og, p["w_out"], mode=c.dense_mode), state


def slstm_decode(p, c: XlstmConfig, x: jnp.ndarray, state):
    z, i, logf, og = _slstm_inputs(p, c, x)
    new_state, h = _slstm_step(
        p, c, state, (z[:, 0], i[:, 0], logf[:, 0], None)
    )
    B, D = x.shape[0], x.shape[2]
    h = h.reshape(B, D).astype(x.dtype)
    y = dense(h * og[:, 0], p["w_out"], mode=c.dense_mode)
    return y[:, None], new_state
