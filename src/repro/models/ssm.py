"""Mamba2 (SSD) block — the state-space mixer used by Zamba2.

Simplified-but-faithful SSD: per-head scalar decay a_t = exp(-softplus(dt) *
A), state S_t = a_t * S_{t-1} + dt * B_t ⊗ x_t, y_t = C_t · S_t + D * x_t,
with a depthwise causal conv in front and a gated output projection — the
structure that matters for compute/memory/roofline and for decode's O(1)
state, which is what long_500k exercises.

Sequence processing uses an associative scan (log-depth, XLA-friendly);
decode is a single recurrence step on the carried state.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels.ops import dense
from repro.models.layers import sds

CONV_K = 4


@dataclasses.dataclass(frozen=True)
class SsmConfig:
    d_model: int
    d_inner: int          # typically 2*d_model
    d_state: int          # N: state dim per channel (zamba2: 64)
    n_heads: int          # channels grouped into heads for dt/A
    dtype: object = jnp.bfloat16
    dense_mode: str = "auto"   # kernels.ops.dense routing for in/out projections

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads


def ssm_specs(c: SsmConfig):
    return {
        "w_in": sds((c.d_model, 2 * c.d_inner), c.dtype),      # x and gate z
        "w_bc": sds((c.d_model, 2 * c.d_state), c.dtype),      # B and C
        "w_dt": sds((c.d_model, c.n_heads), c.dtype),
        "conv_w": sds((CONV_K, c.d_inner), c.dtype),
        "A_log": sds((c.n_heads,), jnp.float32),
        "D": sds((c.n_heads,), jnp.float32),
        "dt_bias": sds((c.n_heads,), jnp.float32),
        "w_out": sds((c.d_inner, c.d_model), c.dtype),
    }


def _conv1d_causal(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv. x: (B,S,Di), w: (K,Di)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    return sum(pad[:, i : i + x.shape[1]] * w[i] for i in range(K))


def _ssd_params(p, c: SsmConfig, u):
    """Shared projections (via `kernels.ops.dense`).  u: (B,S,D) ->
    x:(B,S,Di) z, B, C, dt, a."""
    xz = dense(u, p["w_in"], mode=c.dense_mode)
    x, z = jnp.split(xz, 2, axis=-1)
    x = _conv1d_causal(x, p["conv_w"])
    x = jax.nn.silu(x)
    bc = dense(u, p["w_bc"], mode=c.dense_mode)
    Bm, Cm = jnp.split(bc, 2, axis=-1)                       # (B,S,N)
    dt = jax.nn.softplus(
        dense(u, p["w_dt"], mode=c.dense_mode).astype(jnp.float32) + p["dt_bias"]
    )                                                        # (B,S,H)
    a = jnp.exp(-dt * jnp.exp(p["A_log"]))                   # decay in (0,1)
    return x, z, Bm, Cm, dt, a


SSD_CHUNK = 256  # intra-chunk quadratic window (VMEM-sized; TPU adaptation)


def _ssd_chunked(p, c: SsmConfig, u: jnp.ndarray):
    """Chunked SSD (Mamba2 duality): intra-chunk quadratic form + inter-chunk
    state carry — never materializes per-position (P, N) states, which the
    naive associative scan does at (B,S,H,P,N) (~100 GiB at 4k x 2.5k dims).

    Returns (hidden (B,S,H,P) f32, final state (B,H,P,N))."""
    B_, S, _ = u.shape
    H, P, N = c.n_heads, c.head_dim, c.d_state
    Lc = min(SSD_CHUNK, S)
    if S % Lc:
        raise ValueError(f"seq len {S} must be divisible by ssd chunk {Lc}")
    nc = S // Lc
    x, z, Bm, Cm, dt, a = _ssd_params(p, c, u)
    xh = x.reshape(B_, S, H, P).astype(jnp.float32)
    loga = jnp.log(jnp.maximum(a, 1e-30))                    # (B,S,H)

    def resh(t):  # (B,S,...) -> (nc,B,Lc,...)
        return t.reshape(B_, nc, Lc, *t.shape[2:]).swapaxes(0, 1)

    xs, Bs, Cs, dts, logas = map(resh, (
        xh, Bm.astype(jnp.float32), Cm.astype(jnp.float32), dt, loga))

    s0 = jnp.zeros((B_, H, P, N), jnp.float32)

    def step(s_prev, inp):
        xc, bc, cc, dtc, lac = inp                  # (B,Lc,H,P)/(B,Lc,N)/...
        A = jnp.cumsum(lac, axis=1)                 # (B,Lc,H) decay to pos t
        # intra-chunk: y[t] = sum_{s<=t} exp(A_t - A_s) dt_s (C_t.B_s) x_s
        decay = A[:, :, None, :] - A[:, None, :, :]          # (B,t,s,H)
        causal = jnp.tril(jnp.ones((Lc, Lc), bool))
        decay = jnp.where(causal[None, :, :, None], decay, -jnp.inf)
        gates = jnp.exp(decay) * dtc[:, None, :, :]          # (B,t,s,H)
        scores = jnp.einsum("btn,bsn->bts", cc, bc)          # (B,t,s)
        w = gates * scores[..., None]                        # (B,t,s,H)
        y_intra = jnp.einsum("btsh,bshp->bthp", w, xc)
        # inter-chunk: y[t] += exp(A_t) C_t . s_prev
        y_inter = jnp.exp(A)[..., None] * jnp.einsum(
            "btn,bhpn->bthp", cc, s_prev)
        # state update to end of chunk
        wA = jnp.exp(A[:, -1:, :] - A) * dtc                 # (B,Lc,H)
        s_new = (s_prev * jnp.exp(A[:, -1])[..., None, None]
                 + jnp.einsum("bsh,bshp,bsn->bhpn", wA, xc, bc))
        return s_new, y_intra + y_inter

    state, ys = jax.lax.scan(step, s0, (xs, Bs, Cs, dts, logas))
    y = ys.swapaxes(0, 1).reshape(B_, S, H, P)
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(B_, S, H * P).astype(u.dtype)
    y = y * jax.nn.silu(z)
    return dense(y, p["w_out"], mode=c.dense_mode), state


def ssm_forward(p, c: SsmConfig, u: jnp.ndarray) -> jnp.ndarray:
    """Full-sequence SSD (chunked).  u: (B,S,D)."""
    y, _ = _ssd_chunked(p, c, u)
    return y


def ssm_state_specs(c: SsmConfig, batch: int):
    return {"s": sds((batch, c.n_heads, c.head_dim, c.d_state), jnp.float32),
            "conv": sds((batch, CONV_K - 1, c.d_inner), c.dtype)}


def ssm_prefill(p, c: SsmConfig, u: jnp.ndarray):
    """Returns (y, state) — state carries S_T and the conv tail."""
    B_, S, _ = u.shape
    y, state = _ssd_chunked(p, c, u)
    xz = dense(u, p["w_in"], mode=c.dense_mode)
    x_raw, _ = jnp.split(xz, 2, axis=-1)
    conv_tail = x_raw[:, -(CONV_K - 1):]
    if S < CONV_K - 1:
        conv_tail = jnp.pad(x_raw, ((0, 0), (CONV_K - 1 - S, 0), (0, 0)))
    return y, {"s": state, "conv": conv_tail}


def ssm_decode(p, c: SsmConfig, u: jnp.ndarray, state):
    """One-step recurrence. u: (B,1,D)."""
    B_, _, _ = u.shape
    H, P, N = c.n_heads, c.head_dim, c.d_state
    xz = dense(u, p["w_in"], mode=c.dense_mode)
    x_raw, z = jnp.split(xz, 2, axis=-1)                    # (B,1,Di)
    window = jnp.concatenate([state["conv"], x_raw], axis=1)  # (B,K,Di)
    x = jnp.einsum("bkd,kd->bd", window, p["conv_w"])[:, None]
    x = jax.nn.silu(x)
    bc = dense(u, p["w_bc"], mode=c.dense_mode)
    Bm, Cm = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(dense(u, p["w_dt"], mode=c.dense_mode)
                         .astype(jnp.float32) + p["dt_bias"])
    a = jnp.exp(-dt * jnp.exp(p["A_log"]))                  # (B,1,H)
    xh = x.reshape(B_, 1, H, P).astype(jnp.float32)
    contrib = jnp.einsum("bsh,bshp,bsn->bhpn", dt, xh, Bm.astype(jnp.float32))
    s_new = state["s"] * a[:, 0, :, None, None] + contrib
    y = jnp.einsum("bhpn,bn->bhp", s_new, Cm[:, 0].astype(jnp.float32))
    y = y + p["D"][None, :, None] * xh[:, 0]
    y = y.reshape(B_, 1, H * P).astype(u.dtype)
    y = y * jax.nn.silu(z)
    return dense(y, p["w_out"], mode=c.dense_mode), {"s": s_new, "conv": window[:, 1:]}
