from repro.models.registry import ARCH_NAMES, all_configs, get_config
from repro.models.transformer import (
    cache_specs, decode_step, forward, init_params, loss_fn, param_specs, prefill,
)

__all__ = [
    "ARCH_NAMES", "all_configs", "get_config",
    "cache_specs", "decode_step", "forward", "init_params", "loss_fn",
    "param_specs", "prefill",
]
