"""Shared NN layers: norms, RoPE, MLPs, embeddings.

Parameter convention: every module exposes
  *_specs(cfg...) -> pytree of jax.ShapeDtypeStruct   (used by the dry-run)
and params are materialized from specs by `init_from_specs` (smoke tests /
real training only).  Math runs in f32 where it matters (norms, softmax,
router, rotary), weights are stored in cfg.dtype (bf16 by default).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ops import dense

Pytree = object


# ---------------------------------------------------------------------------
# spec helpers
# ---------------------------------------------------------------------------

def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def init_from_specs(specs: Pytree, key: jax.Array, scale: float = 0.02) -> Pytree:
    """Materialize params from a spec tree: truncated-normal(0, scale) for
    >=2D weights, ones for '*scale*' (norm) leaves, zeros for biases."""
    # jax.tree.flatten_with_path only exists on newer jax; the tree_util
    # spelling works across the versions we support.
    leaves, treedef = jax.tree_util.tree_flatten_with_path(specs)
    keys = jax.random.split(key, max(1, len(leaves)))
    out = []
    for (path, spec), k in zip(leaves, keys):
        name = jax.tree_util.keystr((path[-1],)) if path else ""
        if "scale" in name or "norm_w" in name:
            out.append(jnp.ones(spec.shape, spec.dtype))
        elif "bias" in name or spec.ndim < 2:
            out.append(jnp.zeros(spec.shape, spec.dtype))
        else:
            w = jax.random.truncated_normal(k, -2.0, 2.0, spec.shape, jnp.float32)
            out.append((w * scale).astype(spec.dtype))
    return jax.tree.unflatten(treedef, out)


def stack_specs(specs: Pytree, n: int) -> Pytree:
    """Prepend a stacking dim of size n to every leaf (scan-over-layers)."""
    return jax.tree.map(lambda s: sds((n, *s.shape), s.dtype), specs)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_specs(d: int, dtype) -> Pytree:
    return {"scale": sds((d,), dtype)}


def rmsnorm(p: Pytree, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Apply rotary embedding.  x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) * (jnp.log(theta) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_specs(d: int, f: int, dtype, act: str) -> Pytree:
    if act == "swiglu":
        return {"w_gate": sds((d, f), dtype), "w_up": sds((d, f), dtype),
                "w_down": sds((f, d), dtype)}
    return {"w_up": sds((d, f), dtype), "w_down": sds((f, d), dtype)}


def mlp(p: Pytree, x: jnp.ndarray, act: str, dense_mode: str = "ref") -> jnp.ndarray:
    """MLP with every projection routed through `kernels.ops.dense`, so the
    streaming GPP matmul (fused activation epilogue included) takes over on
    TPU at large shapes; "ref" mode reproduces the plain-jnp math exactly."""
    if act == "swiglu":
        h = (dense(x, p["w_gate"], activation="silu", mode=dense_mode)
             * dense(x, p["w_up"], mode=dense_mode))
    else:
        h = dense(x, p["w_up"], activation="gelu", mode=dense_mode)
    return dense(h, p["w_down"], mode=dense_mode)


# ---------------------------------------------------------------------------
# embeddings / lm head
# ---------------------------------------------------------------------------

def embed_specs(vocab: int, d: int, dtype) -> Pytree:
    return {"embedding": sds((vocab, d), dtype)}


def embed(p: Pytree, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["embedding"], tokens, axis=0)


def unembed(p: Pytree, x: jnp.ndarray) -> jnp.ndarray:
    """Logits in f32 (softmax stability at 100k+ vocabs)."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      p["embedding"].astype(jnp.float32))


def lm_head_specs(vocab: int, d: int, dtype) -> Pytree:
    return {"w_out": sds((vocab, d), dtype)}


def lm_head(p: Pytree, x: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      p["w_out"].astype(jnp.float32))


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean token NLL; logits f32 (B, S, V), labels int (B, S).

    The label logit is picked with a broadcast-compare + reduce (instead of
    take_along_axis) so XLA keeps the op fused and shardable when V is
    sharded over the model axis."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    V = logits.shape[-1]
    onehot = labels[..., None] == jax.lax.broadcasted_iota(
        labels.dtype, (1,) * labels.ndim + (V,), labels.ndim)
    ll = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    return jnp.mean(lse - ll)


def cross_entropy_chunked(head_fn, x: jnp.ndarray, labels: jnp.ndarray,
                          chunk: int = 512) -> jnp.ndarray:
    """Mean token NLL without materializing the full (B, S, V) logits.

    Scans sequence chunks; each chunk projects to logits, reduces to a scalar
    partial loss, and is rematerialized in backward (jax.checkpoint), so peak
    memory is one chunk of logits instead of the whole sequence — the
    difference between ~40 GiB and ~300 MiB per device at 151k vocab."""
    B, S, D = x.shape
    if S % chunk:
        chunk = S  # fall back to unchunked for odd sizes (smoke tests)
    n = S // chunk
    xs = x.reshape(B, n, chunk, D).swapaxes(0, 1)          # (n, B, chunk, D)
    ls = labels.reshape(B, n, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def body(acc, inp):
        xc, lc = inp
        lg = head_fn(xc)                                    # (B, chunk, V) f32
        lse = jax.nn.logsumexp(lg, axis=-1)
        V = lg.shape[-1]
        onehot = lc[..., None] == jax.lax.broadcasted_iota(lc.dtype, (1, 1, V), 2)
        ll = jnp.sum(jnp.where(onehot, lg, 0.0), axis=-1)
        return acc + jnp.sum(lse - ll), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ls))
    return total / (B * S)
