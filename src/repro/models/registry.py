"""--arch name -> ModelConfig lookup for the launcher/dry-run/benchmarks."""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

_MODULES = {
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1_8b",
    "gemma3-12b": "repro.configs.gemma3_12b",
    "qwen2-7b": "repro.configs.qwen2_7b",
    "qwen1.5-0.5b": "repro.configs.qwen1_5_0_5b",
    "musicgen-large": "repro.configs.musicgen_large",
    "llama-3.2-vision-11b": "repro.configs.llama_3_2_vision_11b",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_NAMES}")
    mod = importlib.import_module(_MODULES[name])
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False) -> dict[str, ModelConfig]:
    return {n: get_config(n, smoke) for n in ARCH_NAMES}
